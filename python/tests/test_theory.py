"""Appendix-A theory checks (Theorem 1), executed numerically against the
kernel implementations rather than just the algebra:

1. Sandwich property:   min(b, t) <= prox <= max(b, t)      (Eq. 5/9)
2. Contractive ratio:   r = w^alpha, r -> 1 as d -> inf     (Eq. 6/10)
3. Vanishing variance:  Var[r] -> 0 as alpha -> 0           (Eq. 11)
4. Staleness schedule:  Eq. 4 exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.a3po_loss import fused_decoupled_loss


@settings(max_examples=50, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.integers(0, 64),
)
def test_sandwich_property(seed, d):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    behav = -5.0 * jax.random.uniform(ks[0], (16,))
    theta = -5.0 * jax.random.uniform(ks[1], (16,))
    alpha = ref.staleness_alpha(jnp.full((16,), d))
    prox = ref.interp_prox_logp(behav, theta, alpha)
    lo = jnp.minimum(behav, theta)
    hi = jnp.maximum(behav, theta)
    assert bool(jnp.all(prox >= lo - 1e-6)) and bool(jnp.all(prox <= hi + 1e-6))


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 100))
def test_contractive_ratio_closed_form(seed, d):
    """r = (pi_theta / pi_behav)^alpha — verified through the kernel."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    theta = -3.0 * jax.random.uniform(ks[0], (4, 8)) - 0.1
    behav = -3.0 * jax.random.uniform(ks[1], (4, 8)) - 0.1
    alpha = jnp.full((4,), 1.0 / d)
    _, stats = fused_decoupled_loss(
        theta, behav, jnp.ones((4, 8)), jnp.ones((4, 8)),
        mode=ref.MODE_INTERP, clip_eps=0.2, alpha=alpha,
    )
    w = np.exp(np.asarray(theta - behav))
    np.testing.assert_allclose(stats["ratio"], w ** (1.0 / d), rtol=1e-4)


def test_ratio_tends_to_one_with_staleness():
    theta = jnp.array([[-0.5, -4.0, -1.0]])
    behav = jnp.array([[-3.0, -0.5, -1.0]])
    prev_dev = np.inf
    for d in [1, 2, 4, 16, 256, 1024]:
        alpha = jnp.full((1,), 1.0 / d)
        prox = ref.interp_prox_logp(behav, theta, alpha)
        ratio = np.exp(np.asarray(theta - prox))
        dev = np.abs(ratio - 1.0).max()
        assert dev <= prev_dev + 1e-9
        prev_dev = dev
    assert prev_dev < 0.01  # d=1024: essentially 1


def test_variance_vanishes_as_alpha_shrinks():
    """Var_{a~behav}[w^alpha] -> 0 as alpha -> 0 (Eq. 11), Monte-Carlo."""
    rng = np.random.default_rng(0)
    # A behaviour distribution and importance weights with finite 2nd moment.
    logw = rng.normal(0.0, 1.0, size=200_000)
    w = np.exp(logw)
    variances = []
    for alpha in [1.0, 0.5, 0.25, 0.1, 0.02]:
        variances.append(np.var(w**alpha))
    assert all(b < a for a, b in zip(variances, variances[1:])), variances
    assert variances[-1] < 1e-2


def test_staleness_alpha_schedule_eq4():
    d = jnp.array([0, 1, 2, 5, 100])
    a = ref.staleness_alpha(d)
    np.testing.assert_allclose(a, [0.0, 1.0, 0.5, 0.2, 0.01], rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    alpha=st.floats(0.0, 1.0),
)
def test_prox_is_valid_log_prob_upper_bound(seed, alpha):
    """Geometric interpolation of two (sub)distributions never exceeds
    probability 1: log pi_prox <= 0 when both inputs are log-probs."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    behav = -6.0 * jax.random.uniform(ks[0], (32,))
    theta = -6.0 * jax.random.uniform(ks[1], (32,))
    prox = ref.interp_prox_logp(behav, theta, jnp.full((32,), alpha))
    assert bool(jnp.all(prox <= 1e-6))
