"""L1 correctness: the fused A-3PO decoupled-loss kernel vs the oracle,
across all three modes (sync / recompute / loglinear), plus custom-VJP
verification against the analytic gradient and finite differences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.a3po_loss import fused_decoupled_loss


def _random_batch(seed, b, t):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    theta = jax.random.normal(ks[0], (b, t)) - 2.0
    behav = theta + 0.3 * jax.random.normal(ks[1], (b, t))
    prox = theta + 0.15 * jax.random.normal(ks[2], (b, t))
    adv = jax.random.normal(ks[3], (b, t))
    mask = (jax.random.uniform(ks[4], (b, t)) > 0.25).astype(jnp.float32)
    alpha = jax.random.uniform(ks[5], (b,))
    return theta, behav, prox, adv, mask, alpha


def _mode_kwargs(mode, prox, alpha):
    if mode == ref.MODE_FROZEN:
        return {"prox_logp": prox}
    if mode == ref.MODE_INTERP:
        return {"alpha": alpha}
    return {}


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 17),
    t=st.integers(1, 40),
    mode=st.sampled_from([ref.MODE_COUPLED, ref.MODE_FROZEN, ref.MODE_INTERP]),
    clip_eps=st.floats(0.05, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_across_shapes_and_modes(b, t, mode, clip_eps, seed):
    theta, behav, prox, adv, mask, alpha = _random_batch(seed, b, t)
    kw = _mode_kwargs(mode, prox, alpha)
    loss, stats = fused_decoupled_loss(
        theta, behav, adv, mask, mode=mode, clip_eps=clip_eps, **kw
    )
    r = ref.decoupled_loss_ref(
        theta, behav, adv, mask, mode=mode, clip_eps=clip_eps,
        prox_logp=kw.get("prox_logp"), alpha=kw.get("alpha"),
    )
    np.testing.assert_allclose(loss, r["loss"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(stats["is_weight"], r["is_weight"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(stats["ratio"], r["ratio"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(stats["clipped"], r["clipped"], atol=0)


@pytest.mark.parametrize("mode", [ref.MODE_COUPLED, ref.MODE_FROZEN, ref.MODE_INTERP])
def test_grad_matches_analytic(mode):
    theta, behav, prox, adv, mask, alpha = _random_batch(11, 8, 31)
    kw = _mode_kwargs(mode, prox, alpha)

    loss_fn = lambda th: fused_decoupled_loss(
        th, behav, adv, mask, mode=mode, clip_eps=0.2, **kw
    )[0]
    g = jax.grad(loss_fn)(theta)

    r = ref.decoupled_loss_ref(
        theta, behav, adv, mask, mode=mode, clip_eps=0.2,
        prox_logp=kw.get("prox_logp"), alpha=kw.get("alpha"),
    )
    denom = float(jnp.maximum(jnp.sum(mask), 1.0))
    expected = -(r["dtheta"] * mask) / denom
    np.testing.assert_allclose(g, expected, rtol=1e-4, atol=1e-7)


def test_grad_finite_difference_unclipped_tokens():
    theta, behav, prox, adv, mask, alpha = _random_batch(13, 2, 6)
    mode = ref.MODE_INTERP
    r = ref.decoupled_loss_ref(
        theta, behav, adv, mask, mode=mode, clip_eps=0.2, alpha=alpha
    )

    def f(th):
        return float(
            fused_decoupled_loss(th, behav, adv, mask, mode=mode, clip_eps=0.2,
                                 alpha=alpha)[0]
        )

    g = jax.grad(
        lambda th: fused_decoupled_loss(th, behav, adv, mask, mode=mode,
                                        clip_eps=0.2, alpha=alpha)[0]
    )(theta)
    eps = 1e-3
    for i in range(2):
        for j in range(6):
            # Finite differences only agree away from the clip boundary and
            # where the interp-anchor detachment matches the analytic form:
            # check unclipped, masked tokens.
            if r["clipped"][i, j] > 0 or mask[i, j] == 0:
                continue
            tp = theta.at[i, j].add(eps)
            tm = theta.at[i, j].add(-eps)
            fd = (f(tp) - f(tm)) / (2 * eps)
            # The FD path also moves the (detached-in-grad) anchor, so
            # tolerate the alpha-order difference.
            assert abs(fd - float(g[i, j])) < 0.05 + 0.5 * float(alpha[i]), (
                i, j, fd, float(g[i, j]),
            )


def test_sync_mode_is_standard_ppo():
    # MODE_COUPLED with behav == theta gives ratio 1, iw 1, zero clipping.
    theta = -jnp.ones((4, 8))
    adv = jnp.ones((4, 8))
    mask = jnp.ones((4, 8))
    loss, stats = fused_decoupled_loss(
        theta, theta, adv, mask, mode=ref.MODE_COUPLED, clip_eps=0.2
    )
    np.testing.assert_allclose(stats["ratio"], 1.0, rtol=1e-6)
    np.testing.assert_allclose(stats["is_weight"], 1.0, rtol=1e-6)
    np.testing.assert_allclose(stats["clipped"], 0.0)
    np.testing.assert_allclose(loss, -1.0, rtol=1e-6)


def test_clipping_activates_on_large_ratios():
    theta = jnp.zeros((1, 4))
    behav = theta - 1.0  # ratio e^1 ≈ 2.72 >> 1+eps
    adv = jnp.ones((1, 4))
    mask = jnp.ones((1, 4))
    _, stats = fused_decoupled_loss(
        theta, behav, adv, mask, mode=ref.MODE_COUPLED, clip_eps=0.2
    )
    np.testing.assert_allclose(stats["clipped"], 1.0)
    # Negative advantage on the same ratios: min picks the unclipped branch.
    _, stats2 = fused_decoupled_loss(
        theta, behav, -adv, mask, mode=ref.MODE_COUPLED, clip_eps=0.2
    )
    np.testing.assert_allclose(stats2["clipped"], 0.0)


def test_loglinear_zero_staleness_recovers_coupled():
    # alpha = 0 (d = 0): prox = theta, so ratio = 1 everywhere and the
    # importance weight becomes theta/behav — A-3PO's d=0 degenerate case.
    theta, behav, _, adv, mask, _ = _random_batch(17, 4, 9)
    alpha = jnp.zeros((4,))
    _, stats = fused_decoupled_loss(
        theta, behav, adv, mask, mode=ref.MODE_INTERP, clip_eps=0.2, alpha=alpha
    )
    np.testing.assert_allclose(stats["ratio"], 1.0, rtol=1e-5)
    np.testing.assert_allclose(
        stats["is_weight"], np.exp(np.asarray(theta - behav)), rtol=1e-5
    )


def test_alpha_one_anchors_at_behaviour():
    # alpha = 1 (d = 1): prox = behav — exact decoupled-PPO-with-old-anchor.
    theta, behav, _, adv, mask, _ = _random_batch(19, 4, 9)
    alpha = jnp.ones((4,))
    _, stats = fused_decoupled_loss(
        theta, behav, adv, mask, mode=ref.MODE_INTERP, clip_eps=0.2, alpha=alpha
    )
    np.testing.assert_allclose(stats["is_weight"], 1.0, rtol=1e-5)
    np.testing.assert_allclose(
        stats["ratio"], np.exp(np.asarray(theta - behav)), rtol=1e-5
    )


def test_empty_mask_gives_zero_loss():
    theta, behav, prox, adv, _, alpha = _random_batch(23, 3, 5)
    mask = jnp.zeros((3, 5))
    loss, _ = fused_decoupled_loss(
        theta, behav, adv, mask, mode=ref.MODE_INTERP, clip_eps=0.2, alpha=alpha
    )
    np.testing.assert_allclose(loss, 0.0)
