"""L2 model tests: shapes, parameter plumbing, decode/forward agreement,
Adam behaviour, and the three train-step variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import get_preset, N_METRICS
from compile import model as M
from compile.kernels import ref


CFG = get_preset("tiny")
MC = CFG.model


@pytest.fixture(scope="module")
def params():
    return M.init_params(MC, 0)


@pytest.fixture(scope="module")
def opt_state(params):
    zeros = lambda: {k: jnp.zeros_like(v) for k, v in params.items()}
    return zeros(), zeros()


def random_tokens(seed, b=None, s=None):
    b = b or CFG.train_batch
    s = s or CFG.seq_len
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, MC.vocab)


def test_param_specs_match_init(params):
    specs = M.param_specs(MC)
    assert set(params) == {n for n, _ in specs}
    for name, shape in specs:
        assert params[name].shape == shape, name
    # Count formula in the config matches reality.
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == MC.param_count()


def test_flatten_roundtrip(params):
    flat = M.flatten_params(MC, params)
    back = M.unflatten_params(MC, flat)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(back[k], params[k])


def test_forward_shapes_and_finiteness(params):
    tokens = random_tokens(0, b=4)
    logits = M.forward_logits(MC, params, tokens)
    assert logits.shape == (4, CFG.seq_len, MC.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_causality(params):
    """Changing a future token must not change past logits."""
    tokens = random_tokens(1, b=2)
    logits1 = M.forward_logits(MC, params, tokens)
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % MC.vocab)
    logits2 = M.forward_logits(MC, params, tokens2)
    np.testing.assert_allclose(
        logits1[:, :-1, :], logits2[:, :-1, :], rtol=1e-5, atol=1e-5
    )
    assert not np.allclose(logits1[:, -1, :], logits2[:, -1, :])


def test_decode_agrees_with_forward(params):
    tokens = random_tokens(2, b=CFG.rollout_batch)
    full = M.forward_logits(MC, params, tokens)
    for pos in [CFG.prompt_len, CFG.seq_len - 1]:
        dec = M.decode_logits(MC, params, tokens, jnp.int32(pos))
        np.testing.assert_allclose(dec, full[:, pos - 1, :], rtol=1e-5, atol=1e-5)


def test_sequence_logp_matches_ref(params):
    tokens = random_tokens(3, b=4)
    logp, ent = M.sequence_logp(MC, params, tokens)
    logits = M.forward_logits(MC, params, tokens)[:, :-1, :]
    lp_ref, ent_ref = ref.token_logprob_ref(logits, tokens[:, 1:])
    np.testing.assert_allclose(logp, lp_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ent, ent_ref, rtol=1e-5, atol=1e-5)


def test_adam_moves_toward_gradient(params):
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    grads = {k: jnp.ones_like(x) for k, x in params.items()}
    new_p, new_m, new_v, gnorm = M.adam_update(CFG, params, m, v, grads, jnp.int32(0))
    assert float(gnorm) > 0
    # With all-ones gradients every parameter decreases.
    for k in params:
        assert bool(jnp.all(new_p[k] <= params[k] + 1e-9)), k
        assert bool(jnp.all(new_m[k] != 0.0)) or params[k].size == 0


def test_grad_clip_bounds_update(params):
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    grads = {k: 1e6 * jnp.ones_like(x) for k, x in params.items()}
    new_p, _, _, gnorm = M.adam_update(CFG, params, m, v, grads, jnp.int32(0))
    # Clipped: the applied step is finite and small despite the huge grad.
    delta = max(float(jnp.max(jnp.abs(new_p[k] - params[k]))) for k in params)
    assert delta < 10 * CFG.lr
    assert float(gnorm) > CFG.grad_clip


def _rl_inputs(params, seed=5):
    b, t = CFG.train_batch, CFG.seq_len - 1
    tokens = random_tokens(seed)
    logp, _ = M.sequence_logp(MC, params, tokens)
    mask = jnp.zeros((b, t)).at[:, CFG.prompt_len - 1:].set(1.0)
    adv = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, t)) * mask
    alpha = jnp.full((b,), 0.5)
    return tokens, mask, logp, adv, alpha


@pytest.mark.parametrize("method", ["sync", "recompute", "loglinear"])
def test_train_step_runs_and_updates(params, method):
    mode = M.MODES[method]
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    tokens, mask, behav, adv, alpha = _rl_inputs(params)
    prox = behav
    p2, m2, v2, step2, metrics = M.train_step(
        CFG, mode, params, m, v, jnp.int32(0), tokens, mask, behav, adv, alpha, prox
    )
    assert metrics.shape == (N_METRICS,)
    assert int(step2) == CFG.n_minibatch
    assert np.isfinite(np.asarray(metrics)).all()
    # Parameters actually moved.
    moved = any(
        float(jnp.max(jnp.abs(p2[k] - params[k]))) > 0 for k in params
    )
    assert moved


def test_on_policy_sync_step_has_unit_ratios(params):
    """First minibatch of a sync step on fresh on-policy data: ratio = 1,
    iw = 1, so max/min importance weights hug 1."""
    mode = M.MODES["sync"]
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    tokens, mask, behav, adv, alpha = _rl_inputs(params, seed=7)
    _, _, _, _, metrics = M.train_step(
        CFG, mode, params, m, v, jnp.int32(0), tokens, mask, behav, adv,
        jnp.zeros_like(alpha), behav,
    )
    # metrics[2] = max_iw, metrics[3] = min_iw: sync iw == 1 by construction.
    assert abs(float(metrics[2]) - 1.0) < 1e-4
    assert abs(float(metrics[3]) - 1.0) < 1e-4


def test_pretrain_step_reduces_loss(params):
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    tokens = random_tokens(11)
    mask = jnp.ones((CFG.train_batch, CFG.seq_len - 1))
    p, losses = params, []
    step = jnp.int32(0)
    for _ in range(8):
        p, m, v, step, metrics = M.pretrain_step(CFG, p, m, v, step, tokens, mask)
        losses.append(float(metrics[0]))
    assert losses[-1] < losses[0], losses
