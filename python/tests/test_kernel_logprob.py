"""L1 correctness: token_logprob Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/magnitudes; explicit cases cover block-edge
geometry (rows/vocab not divisible by the default blocks) and the custom
VJP against both autodiff-of-reference and finite differences.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.token_logprob import token_logprob


def _check(logits, targets, **kw):
    lp, ent = token_logprob(logits, targets, **kw)
    lp_r, ent_r = ref.token_logprob_ref(logits, targets)
    np.testing.assert_allclose(lp, lp_r, rtol=1e-5, atol=1e-5)
    # Entropy is a difference of near-equal f32 quantities when the
    # distribution is near-deterministic; compare at f32 cancellation level.
    np.testing.assert_allclose(ent, ent_r, rtol=1e-4, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 70),
    vocab=st.sampled_from([8, 17, 64, 128, 200]),
    scale=st.floats(0.1, 20.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_across_shapes(rows, vocab, scale, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    logits = scale * jax.random.normal(k1, (rows, vocab), jnp.float32)
    targets = jax.random.randint(k2, (rows,), 0, vocab)
    _check(logits, targets)


@pytest.mark.parametrize("shape", [(3, 5, 64), (2, 2, 2, 16), (7,)])
def test_batch_shapes(shape):
    vocab = 32
    k = jax.random.PRNGKey(0)
    logits = jax.random.normal(k, (*shape, vocab))
    targets = jax.random.randint(jax.random.PRNGKey(1), shape, 0, vocab)
    _check(logits, targets)


def test_blocking_choices_do_not_change_results():
    k = jax.random.PRNGKey(2)
    logits = jax.random.normal(k, (48, 96))
    targets = jax.random.randint(jax.random.PRNGKey(3), (48,), 0, 96)
    base, _ = token_logprob(logits, targets)
    for br, bv in [(1, 96), (48, 8), (16, 32), (7, 96)]:
        lp, _ = token_logprob(logits, targets, block_r=br, block_v=bv)
        np.testing.assert_allclose(lp, base, rtol=1e-6, atol=1e-6)


def test_extreme_logits_stable():
    # Online softmax must survive large magnitudes without overflow.
    logits = jnp.array([[1e4, -1e4, 0.0, 5.0], [-1e4, -1e4, -1e4, -1e4]])
    targets = jnp.array([0, 1])
    lp, ent = token_logprob(logits, targets)
    assert np.isfinite(np.asarray(lp)).all()
    assert np.isfinite(np.asarray(ent)).all()
    np.testing.assert_allclose(lp[0], 0.0, atol=1e-5)  # argmax dominates
    # f32 cancellation at |z| ~ 1e4 costs ~3 decimal digits — the point is
    # stability (finite + near log V), not exactness.
    np.testing.assert_allclose(ent[1], np.log(4.0), rtol=1e-3)  # uniform


def test_grad_matches_reference_autodiff():
    k = jax.random.PRNGKey(4)
    logits = 3.0 * jax.random.normal(k, (6, 40))
    targets = jax.random.randint(jax.random.PRNGKey(5), (6,), 0, 40)
    w = jax.random.normal(jax.random.PRNGKey(6), (6,))

    f = lambda z: jnp.sum(token_logprob(z, targets)[0] * w)
    f_ref = lambda z: jnp.sum(ref.token_logprob_ref(z, targets)[0] * w)
    g = jax.grad(f)(logits)
    g_ref = jax.grad(f_ref)(logits)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-6)


def test_grad_finite_difference():
    k = jax.random.PRNGKey(7)
    logits = jax.random.normal(k, (2, 8)).astype(jnp.float64).astype(jnp.float32)
    targets = jnp.array([3, 1])
    f = lambda z: float(jnp.sum(token_logprob(z, targets)[0]))
    g = jax.grad(lambda z: jnp.sum(token_logprob(z, targets)[0]))(logits)
    eps = 1e-3
    for i, j in [(0, 3), (0, 0), (1, 1), (1, 7)]:
        zp = logits.at[i, j].add(eps)
        zm = logits.at[i, j].add(-eps)
        fd = (f(zp) - f(zm)) / (2 * eps)
        assert abs(fd - float(g[i, j])) < 5e-3, (i, j, fd, float(g[i, j]))


def test_entropy_is_stop_gradient():
    logits = jax.random.normal(jax.random.PRNGKey(8), (4, 16))
    targets = jnp.zeros((4,), jnp.int32)
    g = jax.grad(lambda z: jnp.sum(token_logprob(z, targets)[1]))(logits)
    np.testing.assert_allclose(g, jnp.zeros_like(g))


def test_jit_and_nested_grad_compile():
    # The kernel must lower inside jit (the AOT path depends on it).
    logits = jax.random.normal(jax.random.PRNGKey(9), (8, 32))
    targets = jax.random.randint(jax.random.PRNGKey(10), (8,), 0, 32)

    @jax.jit
    def step(z):
        lp, ent = token_logprob(z, targets)
        return jnp.sum(lp) + jnp.sum(ent)

    v1 = step(logits)
    v2 = step(logits)
    np.testing.assert_allclose(v1, v2)
