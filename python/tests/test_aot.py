"""AOT pipeline tests: entry-point signatures, HLO-text lowering, and the
manifest contract that the Rust runtime depends on."""

import json
import os

import pytest

from compile.aot import build_entry_points, lower_preset, to_hlo_text
from compile.config import get_preset, PRESETS, N_METRICS
from compile import model as M

import jax


CFG = get_preset("tiny")


def test_entry_point_inventory():
    names = {ep.name for ep in build_entry_points(CFG)}
    assert names == {
        "init", "decode", "prox_forward",
        "train_sync", "train_recompute", "train_loglinear", "pretrain",
    }


def test_signatures_are_consistent():
    n = len(M.param_names(CFG.model))
    for ep in build_entry_points(CFG):
        if ep.name.startswith("train_"):
            assert len(ep.inputs) == 3 * n + 7
            assert len(ep.outputs) == 3 * n + 2
            assert ep.outputs[-1][1] == (N_METRICS,)
        if ep.name == "decode":
            assert ep.inputs[n][0] == "tokens"
            assert ep.outputs[0][1] == (CFG.rollout_batch, CFG.model.vocab)
        # all dtypes are representable
        for (_, _, d) in ep.inputs + ep.outputs:
            assert d in ("f32", "i32")


def test_train_variants_share_signature():
    eps = {ep.name: ep for ep in build_entry_points(CFG)}
    sigs = [
        [(s, d) for (_, s, d) in eps[f"train_{m}"].inputs]
        for m in ("sync", "recompute", "loglinear")
    ]
    assert sigs[0] == sigs[1] == sigs[2], "train variants must be swappable"


def test_lowering_produces_parseable_hlo_text():
    eps = {ep.name: ep for ep in build_entry_points(CFG)}
    ep = eps["decode"]
    lowered = jax.jit(ep.fn).lower(*ep.example_args())
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # jax >= 0.5 proto ids overflow xla 0.5.1 — text is the contract.
    assert len(text) > 1000


@pytest.mark.slow
def test_lower_preset_writes_manifest(tmp_path):
    out = str(tmp_path / "tiny")
    manifest = lower_preset(CFG, out, only={"init", "decode"})
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["format"] == "hlo-text-v1"
    assert on_disk["preset"] == "tiny"
    assert {e for e in on_disk["executables"]} == {"init", "decode"}
    for name, e in on_disk["executables"].items():
        assert os.path.exists(os.path.join(out, e["file"])), name
        assert e["hlo_bytes"] > 0
    assert manifest["config"]["seq_len"] == CFG.seq_len
    # Param list order is the rust-side packing contract.
    assert [p["name"] for p in on_disk["params"]] == M.param_names(CFG.model)


def test_presets_are_internally_consistent():
    for name, cfg in PRESETS.items():
        assert cfg.seq_len <= cfg.model.max_seq, name
        assert cfg.train_batch % cfg.n_minibatch == 0, name
        assert cfg.rollout_batch % cfg.group_size == 0, name
        assert cfg.model.d_model % cfg.model.n_heads == 0, name
        assert cfg.rl_lr <= cfg.lr, name
