"""L2: the policy model — a decoder-only transformer in pure JAX.

This file defines everything that gets AOT-lowered to HLO text by
``aot.py``: parameter init, the forward pass, single-position decode (the
rollout engine's inner loop), the proximal forward pass (the expensive step
A-3PO removes), and the three training-step variants. The per-token loss and
log-prob/entropy computations call the L1 Pallas kernels.

Parameter pytrees are flat ``dict[str, Array]`` with a deterministic name
order (``param_names``); the same order is serialised into the artifact
manifest so the Rust coordinator can pack/unpack literals positionally.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, RunConfig, N_METRICS
from .kernels.token_logprob import token_logprob
from .kernels.a3po_loss import (
    fused_decoupled_loss,
    MODE_COUPLED,
    MODE_FROZEN,
    MODE_INTERP,
)

# ---------------------------------------------------------------------------
# Parameters


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Deterministic (name, shape) list — the manifest's parameter order."""
    d, v, s, f = cfg.d_model, cfg.vocab, cfg.max_seq, cfg.d_ff
    specs: list[tuple[str, tuple[int, ...]]] = [
        ("embed", (v, d)),
        ("pos_embed", (s, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1_scale", (d,)),
            (p + "ln1_bias", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ln2_scale", (d,)),
            (p + "ln2_bias", (d,)),
            (p + "w1", (d, f)),
            (p + "b1", (f,)),
            (p + "w2", (f, d)),
            (p + "b2", (d,)),
        ]
    specs += [
        ("lnf_scale", (d,)),
        ("lnf_bias", (d,)),
        ("unembed", (d, v)),
    ]
    return specs


def param_names(cfg: ModelConfig) -> list[str]:
    return [n for n, _ in param_specs(cfg)]


def init_params(cfg: ModelConfig, seed) -> dict[str, jnp.ndarray]:
    """Scaled-normal init. ``seed`` may be a traced i32 scalar (AOT entry)."""
    key = jax.random.PRNGKey(seed)
    params: dict[str, jnp.ndarray] = {}
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    for (name, shape), k in zip(specs, keys):
        base = name.rsplit(".", 1)[-1]
        if base.startswith("ln") or base.endswith("_scale"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif base.endswith("_bias") or base.startswith("b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif base in ("wo", "w2"):
            # residual-branch outputs scaled down by depth (GPT-2 style)
            std = 0.02 / jnp.sqrt(2.0 * cfg.n_layers)
            params[name] = std * jax.random.normal(k, shape, jnp.float32)
        else:
            params[name] = 0.02 * jax.random.normal(k, shape, jnp.float32)
    return params


def flatten_params(cfg: ModelConfig, params: dict) -> list[jnp.ndarray]:
    return [params[n] for n in param_names(cfg)]


def unflatten_params(cfg: ModelConfig, flat) -> dict[str, jnp.ndarray]:
    names = param_names(cfg)
    assert len(names) == len(flat), (len(names), len(flat))
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# Forward pass


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _attention(x, p, prefix: str, cfg: ModelConfig):
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim

    def split(w):
        y = x @ p[prefix + w]                       # [b, s, d]
        return y.reshape(b, s, h, hd).transpose(0, 2, 1, 3)  # [b, h, s, hd]

    q, k, v = split("wq"), split("wk"), split("wv")
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((s, s), jnp.float32))
    scores = jnp.where(causal[None, None] > 0, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
    return ctx @ p[prefix + "wo"]


def forward_logits(cfg: ModelConfig, params: dict, tokens) -> jnp.ndarray:
    """tokens i32[B, S] -> logits f32[B, S, V] (pre-LN transformer)."""
    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos_embed"][None, :s, :]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        hx = _layernorm(x, params[p + "ln1_scale"], params[p + "ln1_bias"])
        x = x + _attention(hx, params, p, cfg)
        hm = _layernorm(x, params[p + "ln2_scale"], params[p + "ln2_bias"])
        hm = jax.nn.gelu(hm @ params[p + "w1"] + params[p + "b1"])
        x = x + hm @ params[p + "w2"] + params[p + "b2"]
    x = _layernorm(x, params["lnf_scale"], params["lnf_bias"])
    return x @ params["unembed"]


def sequence_logp(cfg: ModelConfig, params: dict, tokens):
    """Per-position next-token logp/entropy via the L1 kernel.

    tokens i32[B, S] -> (logp f32[B, S-1], entropy f32[B, S-1]) where
    position t scores token ``tokens[:, t+1]`` given the prefix.
    """
    logits = forward_logits(cfg, params, tokens)[:, :-1, :]
    targets = tokens[:, 1:]
    return token_logprob(logits, targets)


def decode_logits(cfg: ModelConfig, params: dict, tokens, pos):
    """Rollout inner loop: logits for the token at position ``pos``.

    tokens i32[B, S] (padded), pos i32[] -> f32[B, V]. The hidden state at
    ``pos - 1`` predicts the token at ``pos``.
    """
    logits = forward_logits(cfg, params, tokens)
    idx = jnp.clip(pos - 1, 0, tokens.shape[1] - 1)
    return jax.lax.dynamic_slice_in_dim(logits, idx, 1, axis=1)[:, 0, :]


# ---------------------------------------------------------------------------
# Adam


def adam_update(cfg: RunConfig, params, m, v, grads, step, lr=None):
    """Adam with bias correction + global-norm gradient clipping."""
    lr = cfg.lr if lr is None else lr
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g)) for g in jax.tree_util.tree_leaves(grads))
    )
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    t = step.astype(jnp.float32) + 1.0
    b1, b2 = cfg.adam_b1, cfg.adam_b2
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    new_p, new_m, new_v = {}, {}, {}
    for name in params:
        g = grads[name] * scale
        mi = b1 * m[name] + (1.0 - b1) * g
        vi = b2 * v[name] + (1.0 - b2) * jnp.square(g)
        mhat = mi / bc1
        vhat = vi / bc2
        new_p[name] = params[name] - lr * mhat / (jnp.sqrt(vhat) + cfg.adam_eps)
        new_m[name] = mi
        new_v[name] = vi
    return new_p, new_m, new_v, gnorm


# ---------------------------------------------------------------------------
# Training steps (one per paper method)


def _masked_mean(x, mask):
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _policy_loss(cfg: RunConfig, params, tokens, mask, behav_logp, adv,
                 alpha, prox_logp, mode: int):
    theta_logp, entropy = sequence_logp(cfg.model, params, tokens)
    loss, stats = fused_decoupled_loss(
        theta_logp,
        behav_logp,
        adv,
        mask,
        mode=mode,
        clip_eps=cfg.clip_eps,
        prox_logp=prox_logp,
        alpha=alpha,
    )
    iw, ratio, clipped = stats["is_weight"], stats["ratio"], stats["clipped"]
    big = 1e30
    aux = {
        "entropy": _masked_mean(entropy, mask),
        "max_iw": jnp.max(jnp.where(mask > 0, iw, -big)),
        "min_iw": jnp.min(jnp.where(mask > 0, iw, big)),
        "clipped_tokens": jnp.sum(clipped * mask),
        "mean_ratio": _masked_mean(ratio, mask),
        "approx_kl": _masked_mean(
            jax.lax.stop_gradient(behav_logp) - jax.lax.stop_gradient(theta_logp),
            mask,
        ),
    }
    return loss, aux


def train_step(cfg: RunConfig, mode: int, params, m, v, step, tokens, mask,
               behav_logp, adv, alpha, prox_logp):
    """One training step = ``n_minibatch`` Adam updates (paper: 4).

    The batch's rows are split into consecutive minibatches; in MODE_FROZEN
    the proximal anchor was computed once (by the separate ``prox_forward``
    executable) before the step and stays frozen across minibatches, exactly
    as in decoupled PPO. Returns new (params, m, v) and the metric vector
    (see config.METRIC_NAMES).
    """
    mb = cfg.minibatch
    loss_fn = lambda p, *args: _policy_loss(cfg, p, *args, mode=mode)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    losses, ents, ratios, kls, gnorms = [], [], [], [], []
    max_iws, min_iws, clip_counts = [], [], []
    for i in range(cfg.n_minibatch):
        sl = slice(i * mb, (i + 1) * mb)
        (loss, aux), grads = grad_fn(
            params, tokens[sl], mask[sl], behav_logp[sl], adv[sl],
            alpha[sl], prox_logp[sl],
        )
        params, m, v, gnorm = adam_update(cfg, params, m, v, grads, step, lr=cfg.rl_lr)
        step = step + 1
        losses.append(loss)
        ents.append(aux["entropy"])
        max_iws.append(aux["max_iw"])
        min_iws.append(aux["min_iw"])
        clip_counts.append(aux["clipped_tokens"])
        ratios.append(aux["mean_ratio"])
        kls.append(aux["approx_kl"])
        gnorms.append(gnorm)

    metrics = jnp.stack([
        jnp.mean(jnp.stack(losses)),
        jnp.mean(jnp.stack(ents)),
        jnp.max(jnp.stack(max_iws)),
        jnp.min(jnp.stack(min_iws)),
        jnp.sum(jnp.stack(clip_counts)),
        jnp.mean(jnp.stack(ratios)),
        jnp.mean(jnp.stack(gnorms)),
        jnp.mean(jnp.stack(kls)),
    ])
    assert metrics.shape == (N_METRICS,)
    return params, m, v, step, metrics


def pretrain_step(cfg: RunConfig, params, m, v, step, tokens, mask):
    """Supervised warm-start: next-token cross-entropy on correct solutions.

    Plays the role of the pretrained instruct model in the paper's setups
    (DESIGN.md substitutions table). Metrics vector layout matches train_step
    (slots beyond loss/entropy are zero).
    """

    def loss_fn(p):
        logp, entropy = sequence_logp(cfg.model, p, tokens)
        return -_masked_mean(logp, mask), _masked_mean(entropy, mask)

    (loss, ent), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    params, m, v, gnorm = adam_update(cfg, params, m, v, grads, step)
    z = jnp.zeros(())
    metrics = jnp.stack([loss, ent, z, z, z, z, gnorm, z])
    return params, m, v, step + 1, metrics


MODES = {"sync": MODE_COUPLED, "recompute": MODE_FROZEN, "loglinear": MODE_INTERP}
