"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth for correctness: pytest/hypothesis sweeps compare
the kernels in ``token_logprob.py`` and ``a3po_loss.py`` against these
implementations across shapes and dtypes. They are also used directly by the
theory tests (sandwich / contractive properties, Appendix A of the paper).
"""

from __future__ import annotations

import jax.numpy as jnp

# Loss-kernel modes -- static trace-time selector, shared with a3po_loss.py.
MODE_COUPLED = 0   # sync GRPO: anchor == behaviour policy (standard PPO clip)
MODE_FROZEN = 1    # decoupled "recompute": prox logp is an explicit input
MODE_INTERP = 2    # A-3PO "loglinear": prox = a*behav + (1-a)*theta (Eq. 3)


def token_logprob_ref(logits: jnp.ndarray, targets: jnp.ndarray):
    """Log-prob of ``targets`` under ``logits`` plus the policy entropy.

    logits: f32[..., V]; targets: i32[...] -> (logp[...], entropy[...]).
    entropy = logsumexp(z) - sum softmax(z) * z  (nats).
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    ex = jnp.exp(logits - m)
    denom = jnp.sum(ex, axis=-1, keepdims=True)
    lse = jnp.squeeze(m + jnp.log(denom), axis=-1)
    tgt_logit = jnp.take_along_axis(
        logits, targets[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    logp = tgt_logit - lse
    p = ex / denom
    entropy = lse - jnp.sum(p * logits, axis=-1)
    return logp, entropy


def interp_prox_logp(behav_logp, theta_logp, alpha):
    """Eq. 3: log pi_prox = alpha*log pi_behav + (1-alpha)*log pi_theta.

    ``alpha`` broadcasts per sequence ([B] against [B, T]).
    """
    a = alpha[..., None] if alpha.ndim + 1 == behav_logp.ndim else alpha
    return a * behav_logp + (1.0 - a) * theta_logp


def staleness_alpha(d):
    """Eq. 4: alpha = 0 when d == 0, 1/d when d >= 1 (d = version lag)."""
    d = jnp.asarray(d, jnp.float32)
    return jnp.where(d >= 1.0, 1.0 / jnp.maximum(d, 1.0), 0.0)


def decoupled_loss_ref(
    theta_logp,
    behav_logp,
    adv,
    mask,
    *,
    mode: int,
    clip_eps: float,
    prox_logp=None,
    alpha=None,
):
    """Decoupled PPO clipped objective (paper Eq. 2) + per-token stats.

    Returns a dict with:
      loss          -- scalar, -(sum obj * mask) / max(sum mask, 1)
      obj           -- f32[B, T] per-token objective (before masking)
      is_weight     -- f32[B, T] importance weight pi_prox / pi_behav
      ratio         -- f32[B, T] trust-region ratio pi_theta / pi_prox
      clipped       -- f32[B, T] 1.0 where the clipped branch is active
      dtheta        -- f32[B, T] analytic d obj / d theta_logp (for VJP tests)

    In MODE_INTERP the anchor is detached (the paper freezes pi_prox), so
    gradients flow only through the explicit ``theta_logp`` in ``ratio``.
    """
    theta_logp = theta_logp.astype(jnp.float32)
    behav_logp = behav_logp.astype(jnp.float32)
    if mode == MODE_COUPLED:
        prox = behav_logp
    elif mode == MODE_FROZEN:
        assert prox_logp is not None
        prox = prox_logp.astype(jnp.float32)
    elif mode == MODE_INTERP:
        assert alpha is not None
        prox = interp_prox_logp(behav_logp, theta_logp, alpha.astype(jnp.float32))
    else:  # pragma: no cover - defensive
        raise ValueError(f"bad mode {mode}")

    log_iw = prox - behav_logp
    is_weight = jnp.exp(log_iw)
    ratio = jnp.exp(theta_logp - prox)
    unclipped = ratio * adv
    clip_ratio = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
    clipped_term = clip_ratio * adv
    obj = is_weight * jnp.minimum(unclipped, clipped_term)
    clipped = (unclipped > clipped_term).astype(jnp.float32)

    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(obj * mask) / denom

    # Analytic per-token gradient of ``obj`` w.r.t. theta_logp with the
    # anchor detached: d obj = iw * adv * ratio on the unclipped branch.
    dtheta = is_weight * adv * ratio * (1.0 - clipped)
    return {
        "loss": loss,
        "obj": obj,
        "is_weight": is_weight,
        "ratio": ratio,
        "clipped": clipped,
        "dtheta": dtheta,
        "prox_logp": prox,
    }
