"""Pallas kernel: fused token log-prob + policy entropy over vocab tiles.

This is the L1 compute hot-spot of the training path: for every token
position we need ``log pi(target | prefix)`` (for the PPO ratio) *and* the
policy entropy (Fig. 4 of the paper) from the same ``[rows, V]`` logits.

TPU adaptation (DESIGN.md "Hardware-Adaptation"): instead of a CUDA-style
row-per-warp reduction, the kernel tiles the vocabulary axis into
VMEM-resident ``[block_r, block_v]`` blocks and maintains an *online softmax*
(flash-attention-style running max / running sum-exp / running
``sum exp*logit``) across vocab tiles, so a full vocab row never needs to be
resident. The BlockSpec grid expresses the HBM<->VMEM schedule.

The backward pass is a second single-sweep Pallas kernel that reuses the
forward's logsumexp residual: ``dlogits = (onehot(tgt) - softmax) * g``.
Entropy is a metrics output only and is non-differentiable by contract.

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); interpret mode lowers to plain HLO so the kernel runs inside
the AOT'd executables. Correctness: ``ref.token_logprob_ref`` via
pytest/hypothesis (python/tests/test_kernel_logprob.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. Rows tile at 8 sublanes * n; vocab tiles at 128 lanes
# (the TPU vector-register shape is (8, 128) for f32). On this testbed the
# kernels run under interpret=True, so these choices shape the HLO loop
# structure rather than real VMEM residency; the VMEM-footprint estimate for
# a real TPU is recorded in DESIGN.md §Perf.
DEFAULT_BLOCK_R = 64
DEFAULT_BLOCK_V = 128

_NEG_INF = -1e30


def _fwd_kernel(logits_ref, tgt_ref, logp_ref, ent_ref, lse_ref,
                m_ref, s_ref, dot_ref, tl_ref, *, block_v: int):
    """Grid = (rows/block_r, V/block_v); vocab axis is innermost."""
    j = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        s_ref[...] = jnp.zeros_like(s_ref)
        dot_ref[...] = jnp.zeros_like(dot_ref)
        tl_ref[...] = jnp.zeros_like(tl_ref)

    z = logits_ref[...].astype(jnp.float32)          # [br, bv]
    cols = j * block_v + jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(z, axis=1))
    scale = jnp.exp(m_old - m_new)
    ex = jnp.exp(z - m_new[:, None])
    s_ref[...] = s_ref[...] * scale + jnp.sum(ex, axis=1)
    dot_ref[...] = dot_ref[...] * scale + jnp.sum(ex * z, axis=1)
    m_ref[...] = m_new

    # The target column lands in exactly one vocab tile; accumulate it.
    tgt = tgt_ref[...].astype(jnp.int32)
    hit = jnp.where(cols == tgt[:, None], z, 0.0)
    tl_ref[...] = tl_ref[...] + jnp.sum(hit, axis=1)

    @pl.when(j == nv - 1)
    def _finish():
        lse = m_ref[...] + jnp.log(s_ref[...])
        logp_ref[...] = tl_ref[...] - lse
        ent_ref[...] = lse - dot_ref[...] / s_ref[...]
        lse_ref[...] = lse


def _bwd_kernel(logits_ref, tgt_ref, lse_ref, g_ref, dlogits_ref, *, block_v: int):
    """Single sweep: dlogits = (onehot(tgt) - softmax(logits)) * g."""
    j = pl.program_id(1)
    z = logits_ref[...].astype(jnp.float32)
    cols = j * block_v + jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    p = jnp.exp(z - lse_ref[...][:, None])
    onehot = (cols == tgt_ref[...].astype(jnp.int32)[:, None]).astype(jnp.float32)
    dlogits_ref[...] = (onehot - p) * g_ref[...][:, None]


def _pick_blocks(rows: int, vocab: int, block_r: int, block_v: int):
    br = min(block_r, rows)
    while rows % br:
        br -= 1
    bv = min(block_v, vocab)
    while vocab % bv:
        bv -= 1
    return br, bv


def _fwd_call(logits, targets, block_r, block_v):
    rows, vocab = logits.shape
    br, bv = _pick_blocks(rows, vocab, block_r, block_v)
    grid = (rows // br, vocab // bv)
    row_spec = pl.BlockSpec((br,), lambda i, j: (i,))
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, block_v=bv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bv), lambda i, j: (i, j)),
            row_spec,
        ],
        out_specs=[row_spec] * 7,
        out_shape=[jax.ShapeDtypeStruct((rows,), jnp.float32)] * 7,
        interpret=True,
    )(logits, targets)
    logp, ent, lse = out[0], out[1], out[2]
    return logp, ent, lse


def _bwd_call(logits, targets, lse, g, block_r, block_v):
    rows, vocab = logits.shape
    br, bv = _pick_blocks(rows, vocab, block_r, block_v)
    grid = (rows // br, vocab // bv)
    row_spec = pl.BlockSpec((br,), lambda i, j: (i,))
    return pl.pallas_call(
        functools.partial(_bwd_kernel, block_v=bv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bv), lambda i, j: (i, j)),
            row_spec,
            row_spec,
            row_spec,
        ],
        out_specs=pl.BlockSpec((br, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, vocab), jnp.float32),
        interpret=True,
    )(logits, targets, lse, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _token_logprob2d(logits, targets, block_r, block_v):
    logp, ent, _ = _fwd_call(logits, targets, block_r, block_v)
    return logp, ent


def _token_logprob2d_fwd(logits, targets, block_r, block_v):
    logp, ent, lse = _fwd_call(logits, targets, block_r, block_v)
    return (logp, ent), (logits, targets, lse)


def _token_logprob2d_bwd(block_r, block_v, res, cts):
    logits, targets, lse = res
    g_logp, _g_ent = cts  # entropy is a metric output: non-differentiable.
    dlogits = _bwd_call(logits, targets, lse, g_logp, block_r, block_v)
    return dlogits, None


_token_logprob2d.defvjp(_token_logprob2d_fwd, _token_logprob2d_bwd)


def token_logprob(logits, targets, *, block_r: int = DEFAULT_BLOCK_R,
                  block_v: int = DEFAULT_BLOCK_V):
    """Fused log-prob + entropy. logits f32[..., V], targets i32[...].

    Returns ``(logp[...], entropy[...])`` (f32). Differentiable w.r.t.
    ``logits`` through ``logp`` only; ``entropy``'s cotangent is ignored
    (it is a stop-gradient metric by contract).
    """
    batch_shape = logits.shape[:-1]
    vocab = logits.shape[-1]
    rows = 1
    for s in batch_shape:
        rows *= s
    z2 = logits.reshape(rows, vocab)
    t2 = targets.reshape(rows).astype(jnp.int32)
    logp, ent = _token_logprob2d(z2, t2, block_r, block_v)
    return logp.reshape(batch_shape), jax.lax.stop_gradient(ent.reshape(batch_shape))
