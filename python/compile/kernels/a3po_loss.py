"""Pallas kernel: fused decoupled-PPO clipped loss with A-3PO interpolation.

One VMEM-resident elementwise pass over ``[block_b, block_t]`` token tiles
computes, per token:

  * the proximal anchor (paper Eq. 3, mode-dependent; see below),
  * the importance weight  ``iw = pi_prox / pi_behav``  (Fig. 5 stats),
  * the trust-region ratio ``r = pi_theta / pi_prox``   (Eq. 2),
  * the clipped objective and the active-branch flag    (Fig. 6 stats),
  * the analytic gradient ``d obj / d theta_logp`` used by the custom VJP.

Modes (static, trace-time — shared with ref.py):
  MODE_COUPLED  sync GRPO          anchor = behaviour policy
  MODE_FROZEN   decoupled recompute anchor = explicit prox_logp input
  MODE_INTERP   A-3PO loglinear     anchor = a*behav + (1-a)*theta, detached

The anchor is *frozen* in every mode (the paper detaches pi_prox), so the
objective's only gradient path is the explicit ``theta_logp`` in the ratio;
on the unclipped branch ``d obj/d theta_logp = iw * adv * r`` and zero on the
clipped branch. The custom VJP applies exactly that, making the kernel safe
under ``jax.grad`` without autodiff through Pallas.

Correctness oracle: ``ref.decoupled_loss_ref`` (pytest + hypothesis sweeps in
python/tests/test_kernel_loss.py, including grad-vs-finite-difference).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import MODE_COUPLED, MODE_FROZEN, MODE_INTERP  # noqa: F401 (re-export)

DEFAULT_BLOCK_B = 32
DEFAULT_BLOCK_T = 128

# Output slots of the fused kernel, in order.
OUT_OBJ, OUT_IW, OUT_RATIO, OUT_CLIPPED, OUT_DTHETA = range(5)


def _loss_kernel(theta_ref, behav_ref, prox_ref, alpha_ref, adv_ref,
                 obj_ref, iw_ref, ratio_ref, clip_ref, dtheta_ref,
                 *, mode: int, clip_eps: float):
    theta = theta_ref[...].astype(jnp.float32)
    behav = behav_ref[...].astype(jnp.float32)

    if mode == MODE_COUPLED:
        prox = behav
    elif mode == MODE_FROZEN:
        prox = prox_ref[...].astype(jnp.float32)
    else:  # MODE_INTERP — Eq. 3, alpha broadcast per sequence row.
        a = alpha_ref[...].astype(jnp.float32)[:, None]
        prox = a * behav + (1.0 - a) * theta

    adv = adv_ref[...].astype(jnp.float32)
    iw = jnp.exp(prox - behav)
    ratio = jnp.exp(theta - prox)
    unclipped = ratio * adv
    clipped_term = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    is_clipped = (unclipped > clipped_term).astype(jnp.float32)

    obj_ref[...] = iw * jnp.minimum(unclipped, clipped_term)
    iw_ref[...] = iw
    ratio_ref[...] = ratio
    clip_ref[...] = is_clipped
    # Analytic gradient with the anchor detached (all modes freeze pi_prox).
    dtheta_ref[...] = iw * adv * ratio * (1.0 - is_clipped)


def _pick(n: int, block: int) -> int:
    b = min(block, n)
    while n % b:
        b -= 1
    return b


def _loss_call(theta, behav, prox, alpha, adv, mode, clip_eps, block_b, block_t):
    bsz, tlen = theta.shape
    bb, bt = _pick(bsz, block_b), _pick(tlen, block_t)
    grid = (bsz // bb, tlen // bt)
    tile = pl.BlockSpec((bb, bt), lambda i, j: (i, j))
    row = pl.BlockSpec((bb,), lambda i, j: (i,))
    outs = pl.pallas_call(
        functools.partial(_loss_kernel, mode=mode, clip_eps=clip_eps),
        grid=grid,
        in_specs=[tile, tile, tile, row, tile],
        out_specs=[tile] * 5,
        out_shape=[jax.ShapeDtypeStruct((bsz, tlen), jnp.float32)] * 5,
        interpret=True,
    )(theta, behav, prox, alpha, adv)
    return outs


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _fused_loss(theta, behav, prox, alpha, adv, mode, clip_eps, block_b, block_t):
    return tuple(_loss_call(theta, behav, prox, alpha, adv, mode, clip_eps,
                            block_b, block_t))


def _fused_loss_fwd(theta, behav, prox, alpha, adv, mode, clip_eps, block_b, block_t):
    outs = _loss_call(theta, behav, prox, alpha, adv, mode, clip_eps,
                      block_b, block_t)
    return tuple(outs), outs[OUT_DTHETA]


def _fused_loss_bwd(mode, clip_eps, block_b, block_t, dtheta_tok, cts):
    # Only the per-token objective is differentiable; the stats outputs
    # (iw / ratio / clipped / dtheta) are metrics and their cotangents are
    # ignored by contract (the training loss never consumes them).
    g_obj = cts[OUT_OBJ]
    d_theta = g_obj * dtheta_tok
    zeros = jnp.zeros_like(dtheta_tok)
    zrow = jnp.zeros(dtheta_tok.shape[0], jnp.float32)
    return d_theta, zeros, zeros, zrow, zeros


_fused_loss.defvjp(_fused_loss_fwd, _fused_loss_bwd)


def fused_decoupled_loss(
    theta_logp,
    behav_logp,
    adv,
    mask,
    *,
    mode: int,
    clip_eps: float,
    prox_logp=None,
    alpha=None,
    block_b: int = DEFAULT_BLOCK_B,
    block_t: int = DEFAULT_BLOCK_T,
):
    """Fused decoupled clipped loss (paper Eq. 2 + Eq. 3) and stats.

    Shapes: theta/behav/adv/mask f32[B, T]; alpha f32[B]; prox f32[B, T].
    Returns ``(loss, stats)`` where ``loss`` is the masked mean negative
    objective and ``stats`` is a dict of per-token f32[B, T] tensors:
    ``is_weight``, ``ratio``, ``clipped`` (all stop-gradient metrics).
    """
    bsz, tlen = theta_logp.shape
    if prox_logp is None:
        prox_logp = jnp.zeros((bsz, tlen), jnp.float32)
    if alpha is None:
        alpha = jnp.zeros((bsz,), jnp.float32)
    outs = _fused_loss(theta_logp, behav_logp, prox_logp, alpha, adv,
                       mode, clip_eps, block_b, block_t)
    obj = outs[OUT_OBJ]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(obj * mask) / denom
    stats = {
        "is_weight": jax.lax.stop_gradient(outs[OUT_IW]),
        "ratio": jax.lax.stop_gradient(outs[OUT_RATIO]),
        "clipped": jax.lax.stop_gradient(outs[OUT_CLIPPED]),
    }
    return loss, stats
