"""Model / run configuration shared between the JAX compile path and the
Rust coordinator.

The Rust side never imports this module: ``aot.py`` serialises everything the
coordinator needs (shapes, dtypes, parameter order, executable signatures)
into ``artifacts/<preset>/manifest.json``.

Presets mirror the paper's two experimental setups, scaled to this testbed
(see DESIGN.md "Paper -> testbed substitutions"):

* ``setup1``  — surrogate for Qwen2.5-1.5B-Instruct on GSM8K
* ``setup2``  — surrogate for Qwen3-8B on DAPO-Math-17k (bigger model,
  longer sequences, harder task)
* ``tiny``    — CI-sized preset used by unit/integration tests
* ``big``     — ~100M-parameter preset for the end-to-end example driver
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


# Vocabulary layout — must match rust/src/env/tokenizer.rs exactly.
# 0..=2 specials, 3 '=', 4..=13 digits, 14.. operators/punctuation.
VOCAB_SIZE = 64
PAD, BOS, EOS, SEP = 0, 1, 2, 3

# Metric vector layout produced by every train executable -- must match
# rust/src/metrics/mod.rs::TRAIN_METRIC_NAMES.
METRIC_NAMES = (
    "loss",
    "entropy",
    "max_is_weight",
    "min_is_weight",
    "clipped_tokens",
    "mean_ratio",
    "grad_norm",
    "approx_kl",
)
N_METRICS = len(METRIC_NAMES)


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer hyper-parameters."""

    vocab: int = VOCAB_SIZE
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512
    max_seq: int = 48

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, v, s, f = self.d_model, self.vocab, self.max_seq, self.d_ff
        per_layer = 4 * d * d + 2 * d * f + f + d + 4 * d  # attn + mlp + lns
        return v * d + s * d + self.n_layers * per_layer + 2 * d + d * v


@dataclass(frozen=True)
class RunConfig:
    """One experimental setup: model + batching + optimisation params."""

    name: str
    model: ModelConfig
    # Rollout geometry. ``group_size`` responses are sampled per prompt
    # (GRPO), so rollout batches are multiples of the group size.
    prompt_len: int = 16
    gen_len: int = 16
    group_size: int = 4
    rollout_batch: int = 32          # sequences generated per decode call
    # Training geometry. The paper uses 4 gradient updates per step.
    train_batch: int = 64            # sequences per training step
    n_minibatch: int = 4
    # Optimisation (paper: Adam, lr 8.5e-6; scaled for surrogate scale).
    # ``lr`` drives the supervised warm start; ``rl_lr`` drives the RL
    # updates (much lower, like the paper's post-training regime).
    lr: float = 3e-4
    rl_lr: float = 5e-5
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    clip_eps: float = 0.2
    grad_clip: float = 1.0
    entropy_bonus: float = 0.0
    # Sampling (paper: temperature 1.0, top-p 1.0, full-vocab top-k).
    temperature: float = 1.0

    @property
    def seq_len(self) -> int:
        s = self.prompt_len + self.gen_len
        assert s <= self.model.max_seq, (s, self.model.max_seq)
        return s

    @property
    def minibatch(self) -> int:
        assert self.train_batch % self.n_minibatch == 0
        return self.train_batch // self.n_minibatch

    def to_json_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["model"]["head_dim"] = self.model.head_dim
        d["model"]["param_count"] = self.model.param_count()
        d["seq_len"] = self.seq_len
        d["minibatch"] = self.minibatch
        d["metric_names"] = list(METRIC_NAMES)
        return d


PRESETS: dict[str, RunConfig] = {
    # CI-sized: fast to lower, fast to run; used by pytest + cargo test.
    "tiny": RunConfig(
        name="tiny",
        model=ModelConfig(d_model=64, n_layers=2, n_heads=4, d_ff=128, max_seq=32),
        prompt_len=12,
        gen_len=8,
        rollout_batch=16,
        train_batch=16,
        lr=1e-3,
        rl_lr=2e-4,
    ),
    # Qwen2.5-1.5B on GSM8K surrogate: 2-step arithmetic, short answers.
    "setup1": RunConfig(
        name="setup1",
        model=ModelConfig(d_model=192, n_layers=4, n_heads=6, d_ff=768, max_seq=48),
        prompt_len=16,
        gen_len=10,
        rollout_batch=32,
        train_batch=64,
        lr=4e-4,
    ),
    # Qwen3-8B on DAPO-Math-17k surrogate: longer chains, bigger model.
    "setup2": RunConfig(
        name="setup2",
        model=ModelConfig(d_model=256, n_layers=6, n_heads=8, d_ff=1024, max_seq=64),
        prompt_len=36,
        gen_len=12,
        rollout_batch=32,
        train_batch=64,
        lr=3e-4,
    ),
    # ~100M-parameter configuration for the end-to-end driver.
    "big": RunConfig(
        name="big",
        model=ModelConfig(d_model=768, n_layers=12, n_heads=12, d_ff=3072, max_seq=64),
        prompt_len=36,
        gen_len=12,
        rollout_batch=16,
        train_batch=32,
        lr=2e-4,
    ),
}


def get_preset(name: str) -> RunConfig:
    try:
        return PRESETS[name]
    except KeyError:
        raise SystemExit(
            f"unknown preset {name!r}; available: {sorted(PRESETS)}"
        ) from None
