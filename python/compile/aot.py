"""AOT pipeline: lower every entry point to HLO *text* + write the manifest.

Python runs only here (``make artifacts``); the Rust coordinator then loads
``artifacts/<preset>/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and
never touches Python again.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Every executable is lowered with ``return_tuple=True``; the Rust runtime
unpacks the result tuple positionally using the signatures recorded in
``manifest.json``.

Usage:
    python -m compile.aot --out-dir ../artifacts --preset tiny --preset setup1
    python -m compile.aot --out-dir ../artifacts --all
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import PRESETS, RunConfig, N_METRICS, METRIC_NAMES, get_preset
from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _sig(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


class EntryPoint:
    """A jax callable plus its flat positional I/O signature."""

    def __init__(self, name, fn, inputs, outputs):
        self.name = name
        self.fn = fn
        self.inputs = inputs    # list of (name, shape, dtype-str)
        self.outputs = outputs  # list of (name, shape, dtype-str)

    def example_args(self):
        dt = {"f32": jnp.float32, "i32": jnp.int32}
        return [_spec(s, dt[d]) for (_, s, d) in self.inputs]

    def manifest_entry(self, filename):
        return {
            "file": filename,
            "inputs": [_sig(n, s, d) for (n, s, d) in self.inputs],
            "outputs": [_sig(n, s, d) for (n, s, d) in self.outputs],
        }


def build_entry_points(cfg: RunConfig) -> list[EntryPoint]:
    mc = cfg.model
    names = M.param_names(mc)
    specs = M.param_specs(mc)
    n = len(names)
    S, T, V = cfg.seq_len, cfg.seq_len - 1, mc.vocab
    B, Br = cfg.train_batch, cfg.rollout_batch

    p_in = [(f"param.{nm}", shp, "f32") for nm, shp in specs]
    m_in = [(f"adam_m.{nm}", shp, "f32") for nm, shp in specs]
    v_in = [(f"adam_v.{nm}", shp, "f32") for nm, shp in specs]

    def unflat(args, k):
        return M.unflatten_params(mc, args[k * n:(k + 1) * n])

    eps: list[EntryPoint] = []

    # --- init(seed) -> params ---------------------------------------------
    def init_fn(seed):
        p = M.init_params(mc, seed)
        return tuple(M.flatten_params(mc, p))

    eps.append(EntryPoint(
        "init", init_fn,
        [("seed", (), "i32")],
        [(f"param.{nm}", shp, "f32") for nm, shp in specs],
    ))

    # --- decode(params, tokens, pos) -> logits ----------------------------
    def decode_fn(*args):
        p = unflat(args, 0)
        tokens, pos = args[n], args[n + 1]
        return (M.decode_logits(mc, p, tokens, pos),)

    eps.append(EntryPoint(
        "decode", decode_fn,
        p_in + [("tokens", (Br, S), "i32"), ("pos", (), "i32")],
        [("logits", (Br, V), "f32")],
    ))

    # --- prox_forward(params, tokens) -> logp -----------------------------
    # The expensive extra forward pass of decoupled PPO ("recompute"); also
    # reused as eval_logp. Its wall-clock per call is Fig. 1's 'recompute'.
    def prox_fn(*args):
        p = unflat(args, 0)
        tokens = args[n]
        logp, _ent = M.sequence_logp(mc, p, tokens)
        return (logp,)

    eps.append(EntryPoint(
        "prox_forward", prox_fn,
        p_in + [("tokens", (B, S), "i32")],
        [("logp", (B, T), "f32")],
    ))

    # --- train_{sync,recompute,loglinear} ---------------------------------
    batch_in = [
        ("step", (), "i32"),
        ("tokens", (B, S), "i32"),
        ("mask", (B, T), "f32"),
        ("behav_logp", (B, T), "f32"),
        ("adv", (B, T), "f32"),
        ("alpha", (B,), "f32"),
        ("prox_logp", (B, T), "f32"),
    ]
    state_out = (
        [(f"param.{nm}", shp, "f32") for nm, shp in specs]
        + [(f"adam_m.{nm}", shp, "f32") for nm, shp in specs]
        + [(f"adam_v.{nm}", shp, "f32") for nm, shp in specs]
        + [("step", (), "i32"), ("metrics", (N_METRICS,), "f32")]
    )

    def make_train(mode):
        def fn(*args):
            p, m_, v_ = unflat(args, 0), unflat(args, 1), unflat(args, 2)
            step, tokens, mask, behav, adv, alpha, prox = args[3 * n:3 * n + 7]
            p2, m2, v2, step2, metrics = M.train_step(
                cfg, mode, p, m_, v_, step, tokens, mask, behav, adv, alpha, prox
            )
            return (
                *M.flatten_params(mc, p2),
                *M.flatten_params(mc, m2),
                *M.flatten_params(mc, v2),
                step2,
                metrics,
            )
        return fn

    for method, mode in M.MODES.items():
        eps.append(EntryPoint(
            f"train_{method}", make_train(mode),
            p_in + m_in + v_in + batch_in,
            state_out,
        ))

    # --- pretrain(params, m, v, step, tokens, mask) -----------------------
    def pretrain_fn(*args):
        p, m_, v_ = unflat(args, 0), unflat(args, 1), unflat(args, 2)
        step, tokens, mask = args[3 * n:3 * n + 3]
        p2, m2, v2, step2, metrics = M.pretrain_step(cfg, p, m_, v_, step, tokens, mask)
        return (
            *M.flatten_params(mc, p2),
            *M.flatten_params(mc, m2),
            *M.flatten_params(mc, v2),
            step2,
            metrics,
        )

    eps.append(EntryPoint(
        "pretrain", pretrain_fn,
        p_in + m_in + v_in + [
            ("step", (), "i32"),
            ("tokens", (B, S), "i32"),
            ("mask", (B, T), "f32"),
        ],
        state_out,
    ))

    return eps


def lower_preset(cfg: RunConfig, out_dir: str, only: set[str] | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": "hlo-text-v1",
        "preset": cfg.name,
        "config": cfg.to_json_dict(),
        "params": [
            {"name": nm, "shape": list(shp), "dtype": "f32"}
            for nm, shp in M.param_specs(cfg.model)
        ],
        "metric_names": list(METRIC_NAMES),
        "executables": {},
    }
    for ep in build_entry_points(cfg):
        if only and ep.name not in only:
            continue
        t0 = time.time()
        lowered = jax.jit(ep.fn).lower(*ep.example_args())
        text = to_hlo_text(lowered)
        fname = f"{ep.name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        entry = ep.manifest_entry(fname)
        entry["sha256_16"] = digest
        entry["hlo_bytes"] = len(text)
        manifest["executables"][ep.name] = entry
        print(f"[aot:{cfg.name}] {ep.name:16s} {len(text)/1e6:7.2f} MB  "
              f"{time.time()-t0:6.1f}s")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", action="append", default=[])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--entry", action="append", default=[],
                    help="lower only these entry points (debug)")
    args = ap.parse_args()
    presets = list(PRESETS) if args.all else (args.preset or ["tiny"])
    only = set(args.entry) or None
    for name in presets:
        cfg = get_preset(name)
        lower_preset(cfg, os.path.join(args.out_dir, name), only)


if __name__ == "__main__":
    main()
