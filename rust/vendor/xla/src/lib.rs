//! API-compatible stub of the `xla` crate (the PJRT/XLA Rust bindings).
//!
//! The real crate links `libxla_extension` and is not part of the hermetic
//! build universe. This stub mirrors exactly the surface
//! `a3po::runtime::pjrt` uses, so `--features pjrt` always compiles and is
//! covered by CI's clippy/build jobs; at *runtime* every entry point fails
//! fast at [`PjRtClient::cpu`] with a clear message. Swap the `xla` path
//! dependency in `rust/Cargo.toml` to a real checkout to execute AOT
//! artifacts for real; no source changes needed.

use std::borrow::Borrow;

/// Error type matching the real crate's role in `?`/`.context()` chains.
///
/// Implements `std::error::Error + Send + Sync + 'static` so it converts
/// into the workspace `anyhow::Error` through the blanket `From`.
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: this build uses the stub `xla` crate (no libxla_extension); \
             point the `xla` path dependency at a real checkout to run PJRT artifacts"
        ))
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes the runtime exchanges with PJRT.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host element types [`Literal::to_vec`] can produce.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!("parsing HLO text {path}")))
    }
}

/// An XLA computation wrapping a parsed HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        // Infallible in the real crate too; unreachable here because no
        // HloModuleProto can be constructed from the stub.
        XlaComputation
    }
}

/// Host-side tensor value crossing the PJRT boundary.
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(Error::unavailable("creating literal"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("reading literal"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("destructuring tuple literal"))
    }
}

/// Device-resident buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("fetching buffer"))
    }
}

/// Compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("executing"))
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// The single runtime failure point: everything the backend does starts
    /// by creating a client.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compiling"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_fast_with_a_pointer_to_the_fix() {
        let err = PjRtClient::cpu().err().expect("stub client must not construct");
        let msg = err.to_string();
        assert!(msg.contains("stub `xla` crate"), "unhelpful message: {msg}");
        assert!(msg.contains("path dependency"), "unhelpful message: {msg}");
    }

    #[test]
    fn error_converts_into_boxed_std_error() {
        // The property the pjrt module relies on for `?` conversions.
        let err: Box<dyn std::error::Error + Send + Sync> =
            Box::new(Error::unavailable("probe"));
        assert!(err.to_string().contains("probe"));
    }
}
