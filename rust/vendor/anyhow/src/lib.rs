//! Hermetic drop-in subset of the `anyhow` error-handling API.
//!
//! The build universe for this repository is fully offline (see the root
//! README): every dependency must live in-tree. This vendored crate
//! implements exactly the surface the workspace uses — `Error`, `Result`,
//! the `anyhow!`/`bail!` macros, and the `Context` extension trait for
//! `Result` and `Option` — with the same semantics as the real crate for
//! those operations (context chains print outermost-first, `?` converts any
//! `std::error::Error`, `Error` itself deliberately does *not* implement
//! `std::error::Error` so the blanket `From` impl stays coherent).

use std::fmt;

/// An error chain: `chain[0]` is the outermost message/context, the last
/// element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (`anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or("unknown error"))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self)?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `?` conversion from any standard error. `Error` itself does not implement
// `std::error::Error`, so this blanket impl cannot overlap the reflexive
// `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to fallible values (`Result` and `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn context_chains_print_outermost_first() {
        let e: Result<()> = Err(io_err()).context("reading config");
        let e = e.unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(e.root_cause(), "missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }

    #[test]
    fn with_context_on_result_and_option() {
        let r: Result<i32> = Err(io_err()).with_context(|| format!("step {}", 3));
        assert_eq!(r.unwrap_err().to_string(), "step 3");
        let o: Result<i32> = None.context("empty");
        assert_eq!(o.unwrap_err().to_string(), "empty");
        let some: Result<i32> = Some(5).context("unused");
        assert_eq!(some.unwrap(), 5);
    }

    #[test]
    fn macros_build_errors() {
        let name = "decode";
        let e = anyhow!("executable {name} missing");
        assert_eq!(e.to_string(), "executable decode missing");
        let e2 = anyhow!("{} of {}", 2, 3);
        assert_eq!(e2.to_string(), "2 of 3");
        fn fail() -> Result<()> {
            bail!("boom {}", 7);
        }
        assert_eq!(fail().unwrap_err().to_string(), "boom 7");
        let owned = anyhow!(String::from("owned"));
        assert_eq!(owned.to_string(), "owned");
    }

    #[test]
    fn context_on_anyhow_error_itself() {
        let base: Result<()> = Err(anyhow!("inner"));
        let e = base.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "inner"]);
    }
}
