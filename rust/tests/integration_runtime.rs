//! Integration tests over the runtime + the native `tiny` preset.
//!
//! These are the rust-side counterpart of the python kernel tests: they
//! prove the backend boundary — manifest-driven packing, executable
//! signatures, determinism, and checkpoint round-trips — with zero
//! artifacts on disk (the native backend needs nothing from `make
//! artifacts`; the same assertions hold against PJRT-compiled HLO when the
//! `pjrt` feature is enabled and artifacts exist).

use std::path::Path;
use std::sync::{Arc, OnceLock};

use a3po::runtime::{checkpoint, HostTensor, Runtime};

fn runtime() -> &'static Arc<Runtime> {
    static RT: OnceLock<Arc<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        std::env::set_var("A3PO_QUIET", "1");
        // Resolves to the built-in native preset: no artifacts exist here.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
        Arc::new(Runtime::load(&dir, None).expect("loading native tiny preset"))
    })
}

#[test]
fn manifest_geometry_is_sane() {
    let m = &runtime().manifest;
    assert_eq!(m.preset.name, "tiny");
    assert_eq!(m.preset.seq_len, m.preset.prompt_len + m.preset.gen_len);
    assert!(m.n_params() > 10);
    assert_eq!(m.metric_names.len(), 8);
    for required in ["init", "decode", "train_loglinear"] {
        assert!(m.executables.contains_key(required), "{required}");
    }
    assert_eq!(runtime().backend_name, "native");
}

#[test]
fn init_is_deterministic_in_seed() {
    let rt = runtime();
    let a = rt.init_params(7).unwrap();
    let b = rt.init_params(7).unwrap();
    let c = rt.init_params(8).unwrap();
    assert_eq!(a.params[0], b.params[0], "same seed must give identical params");
    assert_ne!(a.params[0], c.params[0], "different seeds must differ");
}

#[test]
fn decode_runs_and_is_deterministic() {
    let rt = runtime();
    let geo = &rt.manifest.preset;
    let snapshot = rt.init_params(0).unwrap();
    let decode = rt.exec("decode").unwrap();

    let tokens = HostTensor::i32(
        vec![geo.rollout_batch, geo.seq_len],
        vec![1; geo.rollout_batch * geo.seq_len],
    );
    let pos = HostTensor::scalar_i32(geo.prompt_len as i32);

    let mut run = || {
        let mut refs = snapshot.tensor_refs();
        refs.push(&tokens);
        refs.push(&pos);
        let outs = decode.run_refs(&refs).unwrap();
        outs[0].as_f32().unwrap().to_vec()
    };
    let l1 = run();
    let l2 = run();
    assert_eq!(l1.len(), geo.rollout_batch * geo.vocab);
    assert_eq!(l1, l2, "decode must be deterministic");
    assert!(l1.iter().all(|x| x.is_finite()));
}

#[test]
fn executable_rejects_wrong_arity() {
    let rt = runtime();
    let decode = rt.exec("decode").unwrap();
    let snapshot = rt.init_params(0).unwrap();
    let refs = snapshot.tensor_refs(); // missing tokens+pos
    assert!(decode.run_refs(&refs).is_err());
}

#[test]
fn prox_forward_returns_valid_logprobs() {
    let rt = runtime();
    let geo = &rt.manifest.preset;
    let snapshot = rt.init_params(3).unwrap();
    let prox = rt.exec("prox_forward").unwrap();
    let tokens = HostTensor::i32(
        vec![geo.train_batch, geo.seq_len],
        (0..geo.train_batch * geo.seq_len)
            .map(|i| (i % geo.vocab) as i32)
            .collect(),
    );
    let mut refs = snapshot.tensor_refs();
    refs.push(&tokens);
    let outs = prox.run_refs(&refs).unwrap();
    let logp = outs[0].as_f32().unwrap();
    assert_eq!(logp.len(), geo.train_batch * (geo.seq_len - 1));
    // log-probabilities of a real distribution: <= 0 and > -inf.
    assert!(logp.iter().all(|&x| x <= 1e-5 && x > -50.0));
}

#[test]
fn checkpoint_roundtrip_preserves_params() {
    let rt = runtime();
    let snapshot = rt.init_params(11).unwrap();
    let dir = std::env::temp_dir().join(format!("a3po-ckpt-{}", std::process::id()));
    let base = dir.join("test");
    checkpoint::save(&base, &rt.manifest, &snapshot).unwrap();
    let loaded = checkpoint::load(&base, &rt.manifest).unwrap();
    assert_eq!(loaded.version, snapshot.version);
    assert_eq!(
        checkpoint::expected_elements(&rt.manifest.params) as u64,
        rt.manifest.preset.param_count,
    );
    for ((a, b), spec) in snapshot.params.iter().zip(&loaded.params).zip(&rt.manifest.params) {
        assert_eq!(a, b, "param {} drifted through checkpoint", spec.name);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn concurrent_decode_from_multiple_threads() {
    // The rollout pool shares one decode executable across threads; the
    // backend must serve concurrent executions without corruption.
    let rt = runtime();
    let geo = rt.manifest.preset.clone();
    let snapshot = rt.init_params(0).unwrap();
    let decode = rt.exec("decode").unwrap().clone();

    let reference = {
        let tokens = HostTensor::i32(
            vec![geo.rollout_batch, geo.seq_len],
            vec![2; geo.rollout_batch * geo.seq_len],
        );
        let pos = HostTensor::scalar_i32(geo.prompt_len as i32);
        let mut refs = snapshot.tensor_refs();
        refs.push(&tokens);
        refs.push(&pos);
        decode.run_refs(&refs).unwrap()[0].as_f32().unwrap().to_vec()
    };

    let threads: Vec<_> = (0..4)
        .map(|_| {
            let decode = decode.clone();
            let snapshot = snapshot.clone();
            let geo = geo.clone();
            let reference = reference.clone();
            std::thread::spawn(move || {
                for _ in 0..5 {
                    let tokens = HostTensor::i32(
                        vec![geo.rollout_batch, geo.seq_len],
                        vec![2; geo.rollout_batch * geo.seq_len],
                    );
                    let pos = HostTensor::scalar_i32(geo.prompt_len as i32);
                    let mut refs = snapshot.tensor_refs();
                    refs.push(&tokens);
                    refs.push(&pos);
                    let outs = decode.run_refs(&refs).unwrap();
                    let out = outs[0].as_f32().unwrap();
                    assert_eq!(out, reference.as_slice(), "concurrent decode corrupted output");
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
}
