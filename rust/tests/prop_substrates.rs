//! Property tests on the in-house substrates: JSON round-tripping, RNG
//! distributions, sampler normalisation, tokenizer round-trips, and the
//! expression evaluator vs the task generators.

use a3po::env::tokenizer;
use a3po::env::verifier::eval_expression;
use a3po::sampler::{log_softmax, sample, SamplerConfig};
use a3po::util::json::Json;
use a3po::util::proptest::{check, check_n};
use a3po::util::rng::Pcg64;

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.next_f64() * 2000.0 - 1000.0 * 0.5).round() / 8.0),
            3 => {
                let n = rng.below(12) as usize;
                Json::Str(
                    (0..n)
                        .map(|_| {
                            let c = rng.below(96) as u8 + 32;
                            c as char
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check_n(
        "json roundtrip",
        200,
        |rng: &mut Pcg64| vec![rng.next_u64() % 1_000_000],
        |seed| {
            let mut rng = Pcg64::from_seed(seed[0]);
            let v = random_json(&mut rng, 3);
            let text = v.dump();
            let back = Json::parse(&text).map_err(|e| format!("{e} in {text}"))?;
            if back != v {
                return Err(format!("{back:?} != {v:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_log_softmax_is_normalised_distribution() {
    check_n(
        "log_softmax normalised",
        128,
        |rng: &mut Pcg64| {
            let n = 1 + rng.below(64) as usize;
            (0..n).map(|_| rng.next_f64() * 40.0 - 20.0).collect::<Vec<f64>>()
        },
        |logits| {
            let z: Vec<f32> = logits.iter().map(|&x| x as f32).collect();
            let lp = log_softmax(&z, 1.0);
            let total: f64 = lp.iter().map(|&x| (x as f64).exp()).sum();
            if (total - 1.0).abs() > 1e-4 {
                return Err(format!("sum p = {total}"));
            }
            if lp.iter().any(|&x| x > 1e-6) {
                return Err("log-prob above 0".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sampled_token_always_in_support() {
    check_n(
        "sampler support",
        128,
        |rng: &mut Pcg64| {
            let n = 2 + rng.below(30) as usize;
            (0..n).map(|_| rng.next_f64() * 10.0 - 5.0).collect::<Vec<f64>>()
        },
        |logits| {
            let z: Vec<f32> = logits.iter().map(|&x| x as f32).collect();
            let mut rng = Pcg64::from_seed(1);
            for top_k in [0usize, 1, 3] {
                let cfg = SamplerConfig { top_k, ..Default::default() };
                let (tok, lp) = sample(&z, &cfg, &mut rng);
                if tok < 0 || tok as usize >= z.len() {
                    return Err(format!("token {tok} out of range"));
                }
                if !(lp <= 1e-6 && lp.is_finite()) {
                    return Err(format!("bad logp {lp}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tokenizer_roundtrips_all_expressible_strings() {
    check_n(
        "tokenizer roundtrip",
        256,
        |rng: &mut Pcg64| {
            let chars: Vec<char> = "0123456789+-*%()= ".chars().collect();
            let n = 1 + rng.below(30) as usize;
            (0..n)
                .map(|_| chars[rng.below(chars.len() as u64) as usize] as u64)
                .collect::<Vec<u64>>()
        },
        |codes| {
            let s: String = codes.iter().map(|&c| c as u8 as char).collect();
            let toks = tokenizer::encode(&s);
            let back = tokenizer::decode(&toks);
            if back != s {
                return Err(format!("{back:?} != {s:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_generators_agree_with_evaluator_across_seeds() {
    use a3po::env::{arith::ArithEnv, chain::ChainEnv, TaskEnv};
    check(
        "generator vs evaluator",
        |rng: &mut Pcg64| rng.next_u64() % 100_000,
        |&seed| {
            let mut rng = Pcg64::from_seed(seed);
            let envs: [Box<dyn TaskEnv>; 3] = [
                Box::new(ArithEnv::easy()),
                Box::new(ArithEnv::standard()),
                Box::new(ChainEnv::standard()),
            ];
            for env in &envs {
                let p = env.sample(&mut rng);
                let v = eval_expression(p.prompt.trim_end_matches('='))
                    .ok_or_else(|| format!("unparseable {}", p.prompt))?;
                if v.to_string() != p.answer {
                    return Err(format!(
                        "{}: generator says {}, evaluator {v}",
                        p.prompt, p.answer
                    ));
                }
                // And it must fit the env's declared geometry.
                if p.prompt.len() > env.max_prompt_chars() {
                    return Err(format!("prompt too long: {}", p.prompt));
                }
                if p.answer.len() > env.max_answer_chars() {
                    return Err(format!("answer too long: {}", p.answer));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rng_streams_are_independent() {
    check_n(
        "rng stream independence",
        64,
        |rng: &mut Pcg64| rng.next_u64() % 10_000,
        |&seed| {
            let mut a = Pcg64::new(seed, 1);
            let mut b = Pcg64::new(seed, 2);
            let collisions = (0..256).filter(|_| a.next_u64() == b.next_u64()).count();
            if collisions > 0 {
                return Err(format!("{collisions} collisions between streams"));
            }
            Ok(())
        },
    );
}
