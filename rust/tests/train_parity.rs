//! Golden parity: the stateful train-session path and the positional
//! executable path must run *identical* math. Both paths funnel into the
//! same native step kernels, so this pins them bit-for-bit: same metrics,
//! same published parameters, same optimiser state, for every method, over
//! several steps from the same initialisation and the same batches.
//!
//! Also covers the `a3po-opt-v1` train-state checkpoint round-trip.

use a3po::config::Method;
use a3po::coordinator::batch::TrainBatch;
use a3po::coordinator::trainer::Trainer;
use a3po::metrics::TrainMetrics;
use a3po::runtime::{checkpoint, Runtime, WeightStore};
use a3po::util::rng::Pcg64;

const EXECS: &[&str] =
    &["init", "pretrain", "prox_forward", "train_sync", "train_recompute", "train_loglinear"];

/// Deterministic synthetic batch: random tokens in-vocab, the last
/// `gen_len`-ish positions masked (like real episodes), smooth log-probs
/// and advantages, per-row alpha in [0, 1).
fn synthetic_batch(rng: &mut Pcg64, b: usize, s: usize, vocab: usize) -> TrainBatch {
    let t = s - 1;
    let tokens = (0..b * s).map(|_| rng.below(vocab as u64) as i32).collect();
    let mask = (0..b * t).map(|i| if i % t >= t - 8 { 1.0 } else { 0.0 }).collect();
    let behav_logp = (0..b * t).map(|_| -0.1 - 2.0 * rng.next_f32()).collect();
    let adv = (0..b * t).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
    let alpha = (0..b).map(|_| rng.next_f32()).collect();
    TrainBatch {
        tokens,
        mask,
        behav_logp,
        adv,
        alpha,
        staleness: vec![0; b],
        mean_staleness: 0.0,
        mean_alpha: 0.0,
        mean_reward: 0.0,
        mean_reward_exact: 0.0,
    }
}

fn assert_metrics_eq(a: &TrainMetrics, b: &TrainMetrics, ctx: &str) {
    let pairs = [
        (a.loss, b.loss, "loss"),
        (a.entropy, b.entropy, "entropy"),
        (a.max_is_weight, b.max_is_weight, "max_is_weight"),
        (a.min_is_weight, b.min_is_weight, "min_is_weight"),
        (a.clipped_tokens, b.clipped_tokens, "clipped_tokens"),
        (a.mean_ratio, b.mean_ratio, "mean_ratio"),
        (a.grad_norm, b.grad_norm, "grad_norm"),
        (a.approx_kl, b.approx_kl, "approx_kl"),
    ];
    for (x, y, name) in pairs {
        assert!((x - y).abs() <= 1e-6, "{ctx}: {name} diverged: legacy {x} vs session {y}");
    }
}

fn parity_for(method: Method) {
    std::env::set_var("A3PO_QUIET", "1");
    let rt = Runtime::native("tiny", Some(EXECS)).expect("native tiny runtime");
    let geo = rt.manifest.preset.clone();
    let init = rt.init_params(7).expect("init");

    let mut legacy =
        Trainer::new_without_sessions(&rt, method, init.clone(), WeightStore::new(init.clone()))
            .expect("legacy trainer");
    let mut session = Trainer::new(&rt, method, init.clone(), WeightStore::new(init))
        .expect("session trainer");
    assert!(!legacy.session_active(), "new_without_sessions must pin the positional path");
    assert!(session.session_active(), "native backend must offer train sessions");

    let mut rng = Pcg64::from_seed(0xA3);

    // Warm-start parity (exercises satellite-fixed pretrain unpacking too).
    let pre = synthetic_batch(&mut rng, geo.train_batch, geo.seq_len, geo.vocab);
    for i in 0..2 {
        let ml = legacy.pretrain_step(&pre.tokens, &pre.mask).expect("legacy pretrain");
        let ms = session.pretrain_step(&pre.tokens, &pre.mask).expect("session pretrain");
        assert_metrics_eq(&ml, &ms, &format!("{method:?} pretrain {i}"));
        assert_eq!(
            legacy.snapshot().params,
            session.snapshot().params,
            "{method:?} pretrain {i}: published params diverged"
        );
    }

    // Three RL steps, identical batches down both paths.
    for step in 0..3 {
        let batch = synthetic_batch(&mut rng, geo.train_batch, geo.seq_len, geo.vocab);
        let (ml, _) = legacy.step(batch.clone()).expect("legacy step");
        let (ms, _) = session.step(batch).expect("session step");
        assert!(ml.loss.is_finite() && ml.grad_norm.is_finite(), "non-finite metrics");
        assert_metrics_eq(&ml, &ms, &format!("{method:?} step {step}"));
        assert_eq!(legacy.snapshot().version, session.snapshot().version);
        assert_eq!(
            legacy.snapshot().params,
            session.snapshot().params,
            "{method:?} step {step}: published params diverged"
        );
    }

    // Full optimiser state (params + moments + counter) must agree too.
    assert_eq!(legacy.opt_step(), session.opt_step());
    assert_eq!(legacy.opt_step(), 2 + 3 * geo.n_minibatch as i32);
    assert_eq!(
        legacy.export_state().expect("legacy state"),
        session.export_state().expect("session state"),
        "{method:?}: exported optimiser state diverged"
    );
}

#[test]
fn sync_paths_agree() {
    parity_for(Method::Sync);
}

#[test]
fn recompute_paths_agree() {
    parity_for(Method::Recompute);
}

#[test]
fn loglinear_paths_agree() {
    parity_for(Method::Loglinear);
}

#[test]
fn train_state_round_trips_through_checkpoint() {
    std::env::set_var("A3PO_QUIET", "1");
    let rt = Runtime::native("tiny", Some(EXECS)).expect("native tiny runtime");
    let geo = rt.manifest.preset.clone();
    let init = rt.init_params(3).expect("init");
    let mut trainer =
        Trainer::new(&rt, Method::Loglinear, init.clone(), WeightStore::new(init))
            .expect("trainer");

    let mut rng = Pcg64::from_seed(9);
    let batch = synthetic_batch(&mut rng, geo.train_batch, geo.seq_len, geo.vocab);
    trainer.step(batch).expect("step");

    let state = trainer.export_state().expect("export");
    assert_eq!(state.opt_step, trainer.opt_step());

    let base = std::env::temp_dir().join(format!("a3po-opt-ckpt-{}", std::process::id()));
    checkpoint::save_train_state(&base, &rt.manifest, &state).expect("save");
    let loaded = checkpoint::load_train_state(&base, &rt.manifest).expect("load");
    assert_eq!(loaded, state, "train state did not round-trip bit-identically");
    let _ = std::fs::remove_file(base.with_extension("json"));
    let _ = std::fs::remove_file(base.with_extension("bin"));
}
