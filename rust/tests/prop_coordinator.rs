//! Property tests on coordinator invariants: the staleness-aware alpha
//! (paper Eq. 4), GRPO advantage normalisation, batch assembly, buffer
//! routing/state, and the weight-store versioning contract.
//!
//! Uses the in-house mini-proptest harness (`a3po::util::proptest`).

use a3po::buffer::{Episode, EpisodeBuffer};
use a3po::config::{AlphaSchedule, StalenessPolicy};
use a3po::coordinator::advantage::{broadcast_over_mask, grpo_group_advantages};
use a3po::env::Problem;
use a3po::util::proptest::{check, check_n, gens};
use a3po::util::rng::Pcg64;

fn ep(version: u64, reward: f64, t: usize) -> Episode {
    Episode {
        tokens: vec![1; t + 1],
        behav_logp: vec![-1.0; t],
        mask: vec![1.0; t],
        reward,
        reward_exact: reward.floor(),
        version,
        group: 0,
        text: String::new(),
        problem: Problem { prompt: "p=".into(), answer: "0".into() },
    }
}

#[test]
fn prop_alpha_eq4_bounds_and_monotonicity() {
    // Eq. 4: alpha(0) = 0; alpha(d) = 1/d monotone non-increasing in d,
    // always within [0, 1].
    check("alpha eq4", gens::u64_below(10_000), |&d| {
        let s = AlphaSchedule::InverseD;
        let a = s.alpha(d);
        if d == 0 && a != 0.0 {
            return Err(format!("alpha(0) = {a}"));
        }
        if !(0.0..=1.0).contains(&a) {
            return Err(format!("alpha({d}) = {a} out of [0,1]"));
        }
        if d >= 1 {
            let a_next = s.alpha(d + 1);
            if a_next > a {
                return Err(format!("alpha not monotone at {d}"));
            }
            if (a - 1.0 / d as f32).abs() > 1e-7 {
                return Err(format!("alpha({d}) != 1/d"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_grpo_advantages_zero_mean_and_bounded() {
    check("grpo zero-mean", gens::vec_f64(16, 0.0, 1.0), |rewards| {
        let adv = grpo_group_advantages(rewards);
        let mean: f64 = adv.iter().sum::<f64>() / adv.len() as f64;
        if mean.abs() > 1e-9 {
            return Err(format!("mean {mean}"));
        }
        // Normalised by (std + eps): a loose but real bound is sqrt(n).
        let bound = (rewards.len() as f64).sqrt() + 1e-6;
        if adv.iter().any(|a| a.abs() > bound) {
            return Err(format!("advantage exceeds sqrt(n): {adv:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_grpo_shift_invariant() {
    // Adding a constant to every reward must not change advantages.
    check("grpo shift-invariance", gens::vec_f64(12, 0.0, 1.0), |rewards| {
        let a1 = grpo_group_advantages(rewards);
        let shifted: Vec<f64> = rewards.iter().map(|r| r + 5.0).collect();
        let a2 = grpo_group_advantages(&shifted);
        for (x, y) in a1.iter().zip(&a2) {
            if (x - y).abs() > 1e-6 {
                return Err(format!("{x} != {y}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_broadcast_zero_outside_mask() {
    check("broadcast masks", gens::vec_f64(32, 0.0, 1.0), |m| {
        let mask: Vec<f32> = m.iter().map(|&x| if x > 0.5 { 1.0 } else { 0.0 }).collect();
        let out = broadcast_over_mask(3.5, &mask);
        for (o, mk) in out.iter().zip(&mask) {
            if *mk == 0.0 && *o != 0.0 {
                return Err("nonzero advantage outside mask".into());
            }
            if *mk == 1.0 && (*o - 3.5).abs() > 1e-6 {
                return Err("masked token lost its advantage".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_buffer_never_serves_overstale_groups() {
    // For random interleavings of pushes (at random lagging versions) and
    // pops (at increasing trainer versions), every served group respects
    // d <= max_staleness, and conservation holds:
    // pushed == served + dropped + left.
    check_n(
        "buffer staleness admission",
        64,
        |rng: &mut Pcg64| {
            let n_ops = 1 + rng.below(40) as usize;
            (0..n_ops)
                .map(|_| (rng.below(3), rng.below(6)))
                .collect::<Vec<(u64, u64)>>()
        },
        |ops| {
            let max_staleness = 2u64;
            let buf = EpisodeBuffer::new(StalenessPolicy {
                max_staleness,
                max_buffered: 10_000,
            });
            let mut v_now = 0u64;
            let mut pushed = 0u64;
            let mut served = 0u64;
            for (kind, arg) in ops {
                match kind {
                    0 | 1 => {
                        let v = v_now.saturating_sub(*arg);
                        buf.push_group(vec![ep(v, 1.0, 4)]);
                        pushed += 1;
                    }
                    _ => {
                        v_now += arg;
                        if let Some(groups) = buf.try_pop_groups(1, v_now) {
                            served += 1;
                            for g in &groups {
                                let d = g[0].staleness(v_now);
                                if d > max_staleness {
                                    return Err(format!(
                                        "served staleness {d} > {max_staleness}"
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            let dropped = buf
                .stats
                .dropped_stale_groups
                .load(std::sync::atomic::Ordering::Relaxed);
            let left = buf.len_groups() as u64;
            if pushed != served + dropped + left {
                return Err(format!(
                    "conservation: pushed {pushed} != served {served} + \
                     dropped {dropped} + left {left}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_alpha_schedules_boundary_conditions() {
    // Every schedule must satisfy the paper's boundary condition
    // alpha(0) = 0 (on-policy -> standard PPO); the 1/d family anchors
    // fully at the behaviour policy at d = 1.
    let schedules = [
        AlphaSchedule::InverseD,
        AlphaSchedule::InverseD2,
        AlphaSchedule::Behaviour,
        AlphaSchedule::Constant(0.7),
    ];
    for s in schedules {
        assert_eq!(s.alpha(0), 0.0, "{s:?}");
    }
    assert_eq!(AlphaSchedule::InverseD.alpha(1), 1.0);
    assert_eq!(AlphaSchedule::InverseD2.alpha(1), 1.0);
}

#[test]
fn prop_weight_store_versions_monotone_under_interleaving() {
    use a3po::runtime::{ParamSnapshot, WeightStore};
    check_n(
        "weight store monotonic",
        32,
        |rng: &mut Pcg64| (1 + rng.below(20)) as u64,
        |&n| {
            let store = WeightStore::new(ParamSnapshot::new(0, vec![]));
            let s2 = store.clone();
            let reader = std::thread::spawn(move || {
                let mut last = 0;
                for _ in 0..200 {
                    let v = s2.latest().version;
                    if v < last {
                        return Err(format!("version regressed {last} -> {v}"));
                    }
                    last = v;
                }
                Ok(())
            });
            for v in 1..=n {
                store.publish(ParamSnapshot::new(v, vec![]));
            }
            reader.join().unwrap()?;
            if store.version() != n {
                return Err("final version mismatch".into());
            }
            Ok(())
        },
    );
}
