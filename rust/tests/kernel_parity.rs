//! Property tests pinning the blocked GEMM kernels and the lane-shaped
//! attention/LayerNorm kernels against naive f64 references, and the
//! determinism contract: results are bit-identical across
//! `set_force_serial` on/off, scalar-vs-SIMD register tiles, and
//! batch-sliced vs (batch × head)-parallel attention in-process, and across
//! `A3PO_THREADS=1` vs `A3PO_THREADS=4` and `A3PO_KERNEL=scalar|simd` vs
//! default out-of-process (the pool and the ISA choice are both read once
//! at startup, so the cross-process checks re-run this test binary as a
//! child with the variable set).

use std::sync::Mutex;

use a3po::runtime::native::kernels::{
    self, attention_backward, attention_decode_step, attention_forward, kernel_info,
    layernorm_stats, matmul, matmul_a_bt_acc, matmul_acc, matmul_at_b_acc, matmul_at_b_acc_multi,
    matmul_set, matmul_set_bias_gelu, matmul_set_multi, matmul_set_packed_multi, set_force_serial,
    set_kernel_override, KernelIsa,
};
use a3po::util::rng::Pcg64;

/// Serialises tests that toggle the process-global force-serial flag.
static SERIAL_GUARD: Mutex<()> = Mutex::new(());

fn serial_guard() -> std::sync::MutexGuard<'static, ()> {
    SERIAL_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// Inputs scaled to ±0.25 keep f32 accumulation error well under the 1e-5
/// pin even at the largest k used here (the checks stay deterministic:
/// fixed seeds, fixed shapes).
fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 0.5 - 0.25).collect()
}

/// Random shapes with ragged tails in every dimension (not multiples of the
/// MR/NR/KC tiles), k values crossing the KC=256 block boundary, and both
/// sides of the small-GEMM cutoff.
fn shapes() -> Vec<(usize, usize, usize)> {
    let mut rng = Pcg64::from_seed(41);
    let mut out = vec![
        (1, 1, 1),
        (kernels::MR + 1, kernels::KC + 3, kernels::NR + 5),
        (2 * kernels::MR, 2 * kernels::KC, 2 * kernels::NR),
        (37, 300, 23),
        (64, 513, 31),
    ];
    for _ in 0..10 {
        out.push((
            1 + rng.below(40) as usize,
            1 + rng.below(400) as usize,
            1 + rng.below(48) as usize,
        ));
    }
    out
}

fn ref_ab(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
            c[i * n + j] = acc as f32;
        }
    }
    c
}

fn assert_close(got: &[f32], want: &[f32], what: &str, shape: (usize, usize, usize)) {
    assert_eq!(got.len(), want.len());
    for (idx, (x, y)) in got.iter().zip(want).enumerate() {
        assert!(
            (x - y).abs() <= 1e-5,
            "{what} {shape:?} diverges from naive reference at {idx}: {x} vs {y}"
        );
    }
}

#[test]
fn blocked_ab_matches_naive_reference() {
    let mut rng = Pcg64::from_seed(11);
    for (m, k, n) in shapes() {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let c = matmul(&a, &b, m, k, n);
        assert_close(&c, &ref_ab(&a, &b, m, k, n), "a·b", (m, k, n));
    }
}

#[test]
fn blocked_at_b_matches_naive_reference() {
    let mut rng = Pcg64::from_seed(12);
    for (m, k, n) in shapes() {
        // a is [k, m]; reference via explicit transpose.
        let a = randv(&mut rng, k * m);
        let b = randv(&mut rng, k * n);
        let mut c = vec![0.0f32; m * n];
        matmul_at_b_acc(&mut c, &a, &b, k, m, n);
        let mut at = vec![0.0f32; m * k];
        for p in 0..k {
            for i in 0..m {
                at[i * k + p] = a[p * m + i];
            }
        }
        assert_close(&c, &ref_ab(&at, &b, m, k, n), "aᵀ·b", (m, k, n));
    }
}

#[test]
fn blocked_a_bt_matches_naive_reference() {
    let mut rng = Pcg64::from_seed(13);
    for (m, k, n) in shapes() {
        // b is [n, k]; reference via explicit transpose.
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, n * k);
        let mut c = vec![0.0f32; m * n];
        matmul_a_bt_acc(&mut c, &a, &b, m, k, n);
        let mut bt = vec![0.0f32; k * n];
        for j in 0..n {
            for p in 0..k {
                bt[p * n + j] = b[j * k + p];
            }
        }
        assert_close(&c, &ref_ab(&a, &bt, m, k, n), "a·bᵀ", (m, k, n));
    }
}

#[test]
fn all_variants_bit_identical_serial_vs_threaded() {
    let _g = serial_guard();
    let mut rng = Pcg64::from_seed(14);
    for (m, k, n) in shapes() {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let a_t = randv(&mut rng, k * m);
        let b_t = randv(&mut rng, n * k);

        let mut results: Vec<Vec<Vec<f32>>> = Vec::new();
        for serial in [false, true] {
            set_force_serial(serial);
            let ab = matmul(&a, &b, m, k, n);
            let mut atb = vec![0.0f32; m * n];
            matmul_at_b_acc(&mut atb, &a_t, &b, k, m, n);
            let mut abt = vec![0.0f32; m * n];
            matmul_a_bt_acc(&mut abt, &a, &b_t, m, k, n);
            results.push(vec![ab, atb, abt]);
        }
        set_force_serial(false);
        for (v, name) in ["a·b", "aᵀ·b", "a·bᵀ"].iter().enumerate() {
            assert_eq!(
                results[0][v], results[1][v],
                "{name} at {:?} not bit-identical across force_serial",
                (m, k, n)
            );
        }
    }
}

#[test]
fn set_variant_bit_identical_to_acc_from_zero() {
    let mut rng = Pcg64::from_seed(15);
    for (m, k, n) in shapes() {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut c_set = vec![f32::NAN; m * n];
        matmul_set(&mut c_set, &a, &b, m, k, n);
        let mut c_acc = vec![0.0f32; m * n];
        matmul_acc(&mut c_acc, &a, &b, m, k, n);
        assert_eq!(c_set, c_acc, "set vs acc-from-zero at {:?}", (m, k, n));
    }
}

/// The tentpole invariant: the scalar and AVX2 register tiles produce
/// bit-identical results (no tolerance) over ragged shapes — `m % MR != 0`,
/// `n % NR != 0`, `k % KC != 0` — for every GEMM variant including the
/// fused bias+GELU epilogue and the packed entry.
#[test]
fn scalar_vs_simd_bit_identical_over_ragged_shapes() {
    let _g = serial_guard();
    if !kernel_info().simd_available {
        eprintln!("skipping scalar-vs-SIMD bit-equality: no AVX2 on this host");
        return;
    }
    let mut rng = Pcg64::from_seed(17);
    for (m, k, n) in shapes() {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let a_t = randv(&mut rng, k * m);
        let b_t = randv(&mut rng, n * k);
        let bias = randv(&mut rng, n);
        let packed = kernels::PackedB::pack(&b, k, n);

        let mut results: Vec<Vec<Vec<f32>>> = Vec::new();
        for isa in [KernelIsa::Scalar, KernelIsa::Avx2] {
            set_kernel_override(Some(isa));
            let ab = matmul(&a, &b, m, k, n);
            let mut atb = vec![0.0f32; m * n];
            matmul_at_b_acc(&mut atb, &a_t, &b, k, m, n);
            let mut abt = vec![0.0f32; m * n];
            matmul_a_bt_acc(&mut abt, &a, &b_t, m, k, n);
            let mut pre = vec![f32::NAN; m * n];
            let mut act = vec![f32::NAN; m * n];
            matmul_set_bias_gelu(&mut pre, &mut act, &a, &b, &bias, m, k, n);
            let mut pk = vec![f32::NAN; m * n];
            kernels::matmul_set_packed(&mut pk, &a, &packed, m);
            results.push(vec![ab, atb, abt, pre, act, pk]);
        }
        set_kernel_override(None);
        for (v, name) in ["a·b", "aᵀ·b", "a·bᵀ", "fused pre", "fused act", "packed"]
            .iter()
            .enumerate()
        {
            assert_eq!(
                results[0][v], results[1][v],
                "{name} at {:?} not bit-identical between scalar and SIMD tiles",
                (m, k, n)
            );
        }
    }
}

/// The fused multi-B entry points must be bit-identical to three separate
/// single-B calls over the same ragged shapes.
#[test]
fn multi_b_bit_identical_to_single_calls() {
    let mut rng = Pcg64::from_seed(18);
    for (m, k, n) in shapes() {
        let a = randv(&mut rng, m * k);
        let a_t = randv(&mut rng, k * m);
        let bs: Vec<Vec<f32>> = (0..3).map(|_| randv(&mut rng, k * n)).collect();

        let mut single: Vec<Vec<f32>> = (0..3).map(|_| vec![f32::NAN; m * n]).collect();
        for (c, b) in single.iter_mut().zip(bs.iter()) {
            matmul_set(c, &a, b, m, k, n);
        }
        let mut multi: Vec<Vec<f32>> = (0..3).map(|_| vec![f32::NAN; m * n]).collect();
        {
            let (c0, rest) = multi.split_first_mut().unwrap();
            let (c1, rest) = rest.split_first_mut().unwrap();
            let c2 = &mut rest[0];
            matmul_set_multi(
                [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                &a,
                [&bs[0], &bs[1], &bs[2]],
                m,
                k,
                n,
            );
        }
        assert_eq!(single, multi, "matmul_set_multi vs singles at {:?}", (m, k, n));

        let seed: Vec<Vec<f32>> = (0..3).map(|_| randv(&mut rng, m * n)).collect();
        let mut single_acc = seed.clone();
        for (c, b) in single_acc.iter_mut().zip(bs.iter()) {
            matmul_at_b_acc(c, &a_t, b, k, m, n);
        }
        let mut multi_acc = seed;
        {
            let (c0, rest) = multi_acc.split_first_mut().unwrap();
            let (c1, rest) = rest.split_first_mut().unwrap();
            let c2 = &mut rest[0];
            matmul_at_b_acc_multi(
                [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                &a_t,
                [&bs[0], &bs[1], &bs[2]],
                k,
                m,
                n,
            );
        }
        assert_eq!(single_acc, multi_acc, "matmul_at_b_acc_multi vs singles at {:?}", (m, k, n));

        let packed: Vec<kernels::PackedB> =
            bs.iter().map(|b| kernels::PackedB::pack(b, k, n)).collect();
        let mut multi_packed: Vec<Vec<f32>> = (0..3).map(|_| vec![f32::NAN; m * n]).collect();
        {
            let (c0, rest) = multi_packed.split_first_mut().unwrap();
            let (c1, rest) = rest.split_first_mut().unwrap();
            let c2 = &mut rest[0];
            matmul_set_packed_multi(
                [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                &a,
                [&packed[0], &packed[1], &packed[2]],
                m,
            );
        }
        assert_eq!(single, multi_packed, "matmul_set_packed_multi vs singles at {:?}", (m, k, n));
    }
}

// ---------------------------------------------------------------------------
// Attention + LayerNorm parity (the lane-shaped non-GEMM kernels)

/// Ragged attention shapes: `hd` and window lengths on both sides of the
/// 8-lane width, head counts that do not divide anything evenly.
fn attn_shapes() -> Vec<(usize, usize, usize, usize)> {
    vec![
        (1, 1, 1, 1),
        (2, 5, 3, 7),
        (1, 17, 2, 9),
        (3, 8, 2, 12),
        (2, 9, 1, 19),
        (1, 23, 5, 8),
        (2, 12, 4, 16),
    ]
}

fn assert_close_at(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len());
    for (idx, (x, y)) in got.iter().zip(want).enumerate() {
        assert!(
            (x - y).abs() <= tol,
            "{what} diverges from naive reference at {idx}: {x} vs {y}"
        );
    }
}

/// Naive f64 reference of causal multi-head attention forward. Uses the
/// kernel's own f32 `1/sqrt(hd)` so the comparison measures accumulation
/// error only.
fn ref_attention_forward(
    b: usize,
    s: usize,
    h: usize,
    hd: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let d = h * hd;
    let scale = (1.0 / (hd as f32).sqrt()) as f64;
    let mut probs = vec![0.0f32; b * h * s * s];
    let mut ctx = vec![0.0f32; b * s * d];
    for bi in 0..b {
        for hh in 0..h {
            let col = hh * hd;
            for i in 0..s {
                let mut scores = vec![0.0f64; i + 1];
                for (j, sc) in scores.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for t in 0..hd {
                        acc += q[(bi * s + i) * d + col + t] as f64
                            * k[(bi * s + j) * d + col + t] as f64;
                    }
                    *sc = acc * scale;
                }
                let mx = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let mut denom = 0.0f64;
                for sc in scores.iter_mut() {
                    *sc = (*sc - mx).exp();
                    denom += *sc;
                }
                for (j, sc) in scores.iter().enumerate() {
                    probs[((bi * h + hh) * s + i) * s + j] = (sc / denom) as f32;
                }
                for t in 0..hd {
                    let mut acc = 0.0f64;
                    for (j, sc) in scores.iter().enumerate() {
                        acc += sc / denom * v[(bi * s + j) * d + col + t] as f64;
                    }
                    ctx[(bi * s + i) * d + col + t] = acc as f32;
                }
            }
        }
    }
    (probs, ctx)
}

/// Naive f64 reference of attention backward, reading the kernel-produced
/// f32 `probs` (that is the kernel's own input contract).
#[allow(clippy::too_many_arguments)]
fn ref_attention_backward(
    b: usize,
    s: usize,
    h: usize,
    hd: usize,
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dctx: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let d = h * hd;
    let scale = (1.0 / (hd as f32).sqrt()) as f64;
    let mut dq = vec![0.0f64; b * s * d];
    let mut dk = vec![0.0f64; b * s * d];
    let mut dv = vec![0.0f64; b * s * d];
    for bi in 0..b {
        for hh in 0..h {
            let col = hh * hd;
            for i in 0..s {
                let pbase = ((bi * h + hh) * s + i) * s;
                let mut dprobs = vec![0.0f64; i + 1];
                let mut rowdot = 0.0f64;
                for (j, dp) in dprobs.iter_mut().enumerate() {
                    let pj = probs[pbase + j] as f64;
                    let mut acc = 0.0f64;
                    for t in 0..hd {
                        acc += dctx[(bi * s + i) * d + col + t] as f64
                            * v[(bi * s + j) * d + col + t] as f64;
                    }
                    *dp = acc;
                    rowdot += acc * pj;
                    for t in 0..hd {
                        dv[(bi * s + j) * d + col + t] +=
                            pj * dctx[(bi * s + i) * d + col + t] as f64;
                    }
                }
                for (j, dp) in dprobs.iter().enumerate() {
                    let pj = probs[pbase + j] as f64;
                    let ds = pj * (dp - rowdot) * scale;
                    for t in 0..hd {
                        dq[(bi * s + i) * d + col + t] +=
                            ds * k[(bi * s + j) * d + col + t] as f64;
                        dk[(bi * s + j) * d + col + t] +=
                            ds * q[(bi * s + i) * d + col + t] as f64;
                    }
                }
            }
        }
    }
    let down = |x: Vec<f64>| x.into_iter().map(|v| v as f32).collect::<Vec<f32>>();
    (down(dq), down(dk), down(dv))
}

#[test]
fn attention_forward_matches_naive_reference() {
    let mut rng = Pcg64::from_seed(51);
    for (b, s, h, hd) in attn_shapes() {
        let d = h * hd;
        let q = randv(&mut rng, b * s * d);
        let k = randv(&mut rng, b * s * d);
        let v = randv(&mut rng, b * s * d);
        // NaN-poisoned outputs double as an overwrite check.
        let mut probs = vec![f32::NAN; b * h * s * s];
        let mut ctx = vec![f32::NAN; b * s * d];
        attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
        let (rp, rc) = ref_attention_forward(b, s, h, hd, &q, &k, &v);
        let what = format!("attention probs {:?}", (b, s, h, hd));
        assert_close_at(&probs, &rp, 1e-5, &what);
        let what = format!("attention ctx {:?}", (b, s, h, hd));
        assert_close_at(&ctx, &rc, 1e-5, &what);
    }
}

#[test]
fn attention_backward_matches_naive_reference() {
    let mut rng = Pcg64::from_seed(52);
    for (b, s, h, hd) in attn_shapes() {
        let d = h * hd;
        let q = randv(&mut rng, b * s * d);
        let k = randv(&mut rng, b * s * d);
        let v = randv(&mut rng, b * s * d);
        let dctx = randv(&mut rng, b * s * d);
        let mut probs = vec![0.0f32; b * h * s * s];
        let mut ctx = vec![0.0f32; b * s * d];
        attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
        let mut dq = vec![0.0f32; b * s * d];
        let mut dk = vec![0.0f32; b * s * d];
        let mut dv = vec![0.0f32; b * s * d];
        attention_backward(b, s, h, hd, &probs, &q, &k, &v, &dctx, &mut dq, &mut dk, &mut dv);
        let (rq, rk, rv) = ref_attention_backward(b, s, h, hd, &probs, &q, &k, &v, &dctx);
        for (got, want, name) in [(&dq, &rq, "dq"), (&dk, &rk, "dk"), (&dv, &rv, "dv")] {
            let what = format!("attention {name} {:?}", (b, s, h, hd));
            assert_close_at(got, want, 5e-5, &what);
        }
    }
}

/// Decode at the last position over the same caches must match the full
/// window bit-for-bit (the decode head replays the forward head exactly).
#[test]
fn attention_decode_bit_identical_to_full_window() {
    let mut rng = Pcg64::from_seed(53);
    for (b, s, h, hd) in attn_shapes() {
        let d = h * hd;
        let q = randv(&mut rng, b * s * d);
        let k = randv(&mut rng, b * s * d);
        let v = randv(&mut rng, b * s * d);
        let mut probs = vec![0.0f32; b * h * s * s];
        let mut ctx = vec![0.0f32; b * s * d];
        attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
        let pos = s - 1;
        let mut qlast = vec![0.0f32; b * d];
        for r in 0..b {
            qlast[r * d..(r + 1) * d]
                .copy_from_slice(&q[(r * s + pos) * d..(r * s + pos + 1) * d]);
        }
        let mut step = vec![f32::NAN; b * d];
        attention_decode_step(b, s, pos, h, hd, &qlast, &k, &v, &mut step);
        for r in 0..b {
            assert_eq!(
                &ctx[(r * s + pos) * d..(r * s + pos + 1) * d],
                &step[r * d..(r + 1) * d],
                "decode vs full window at {:?}",
                (b, s, h, hd)
            );
        }
    }
}

/// Scalar vs AVX2 twins, bit-for-bit, over the ragged shapes: attention
/// forward/backward/decode and LayerNorm.
#[test]
fn attention_layernorm_scalar_vs_simd_bit_identical() {
    let _g = serial_guard();
    if !kernel_info().simd_available {
        eprintln!("skipping attention scalar-vs-SIMD bit-equality: no AVX2 on this host");
        return;
    }
    let mut rng = Pcg64::from_seed(54);
    for (b, s, h, hd) in attn_shapes() {
        let d = h * hd;
        let q = randv(&mut rng, b * s * d);
        let k = randv(&mut rng, b * s * d);
        let v = randv(&mut rng, b * s * d);
        let dctx = randv(&mut rng, b * s * d);
        let lsc = randv(&mut rng, d);
        let lbs = randv(&mut rng, d);
        let mut results: Vec<Vec<Vec<f32>>> = Vec::new();
        for isa in [KernelIsa::Scalar, KernelIsa::Avx2] {
            set_kernel_override(Some(isa));
            let mut probs = vec![0.0f32; b * h * s * s];
            let mut ctx = vec![0.0f32; b * s * d];
            attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
            let mut dq = vec![0.0f32; b * s * d];
            let mut dk = vec![0.0f32; b * s * d];
            let mut dv = vec![0.0f32; b * s * d];
            attention_backward(b, s, h, hd, &probs, &q, &k, &v, &dctx, &mut dq, &mut dk, &mut dv);
            let mut step = vec![0.0f32; b * d];
            attention_decode_step(b, s, s - 1, h, hd, &q[..b * d], &k, &v, &mut step);
            let (ln_y, ln_m, ln_i) = layernorm_stats(&q, &lsc, &lbs, b * s, d);
            results.push(vec![probs, ctx, dq, dk, dv, step, ln_y, ln_m, ln_i]);
        }
        set_kernel_override(None);
        let names = ["probs", "ctx", "dq", "dk", "dv", "decode ctx", "ln y", "ln mean", "ln inv"];
        for (vi, name) in names.iter().enumerate() {
            assert_eq!(
                results[0][vi], results[1][vi],
                "{name} at {:?} not bit-identical between scalar and SIMD",
                (b, s, h, hd)
            );
        }
    }
}

/// The (batch × head) grain can never change a result: head-parallel
/// (threaded), forced-serial, and per-batch-row sliced calls (the old
/// batch grain) must agree bit-for-bit.
#[test]
fn attention_bit_identical_across_grains() {
    let _g = serial_guard();
    // Big enough that b*h*s*s*hd crosses the parallel work threshold.
    let (b, s, h, hd) = (4, 24, 4, 16);
    let d = h * hd;
    let mut rng = Pcg64::from_seed(55);
    let q = randv(&mut rng, b * s * d);
    let k = randv(&mut rng, b * s * d);
    let v = randv(&mut rng, b * s * d);
    let dctx = randv(&mut rng, b * s * d);

    let run = |serial: bool| {
        set_force_serial(serial);
        let mut probs = vec![0.0f32; b * h * s * s];
        let mut ctx = vec![0.0f32; b * s * d];
        attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
        let mut dq = vec![0.0f32; b * s * d];
        let mut dk = vec![0.0f32; b * s * d];
        let mut dv = vec![0.0f32; b * s * d];
        attention_backward(b, s, h, hd, &probs, &q, &k, &v, &dctx, &mut dq, &mut dk, &mut dv);
        set_force_serial(false);
        (probs, ctx, dq, dk, dv)
    };
    let threaded = run(false);
    let serial = run(true);
    assert_eq!(threaded, serial, "attention not bit-identical across serial vs head-parallel");

    // Batch-sliced calls: one call per batch row, each below the parallel
    // threshold — the old batch-parallel partition.
    let mut probs1 = vec![0.0f32; b * h * s * s];
    let mut ctx1 = vec![0.0f32; b * s * d];
    for bi in 0..b {
        attention_forward(
            1,
            s,
            h,
            hd,
            &q[bi * s * d..(bi + 1) * s * d],
            &k[bi * s * d..(bi + 1) * s * d],
            &v[bi * s * d..(bi + 1) * s * d],
            &mut probs1[bi * h * s * s..(bi + 1) * h * s * s],
            &mut ctx1[bi * s * d..(bi + 1) * s * d],
        );
    }
    assert_eq!(threaded.0, probs1, "batch-sliced probs diverged from head-parallel");
    assert_eq!(threaded.1, ctx1, "batch-sliced ctx diverged from head-parallel");
}

// ---------------------------------------------------------------------------
// Cross-process bit-equality: the pool sizes itself from A3PO_THREADS once
// at first use, so different thread counts need separate processes.

/// FNV-1a over the raw bit patterns of every result the kernel suite
/// produces — GEMMs, attention forward/backward/decode, and LayerNorm —
/// so any accumulation-order difference on any kernel changes this value.
fn kernel_checksum() -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    let mut fold = |buf: &[f32]| {
        for &x in buf {
            h = (h ^ x.to_bits() as u64).wrapping_mul(FNV_PRIME);
        }
    };
    let mut rng = Pcg64::from_seed(16);
    // Shapes chosen to exercise the parallel path (above the ~128k
    // multiply-add serial cutoff) as well as ragged serial ops.
    for (m, k, n) in [(96, 128, 64), (256, 256, 64), (33, 300, 21), (5, 7, 3)] {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let a_t = randv(&mut rng, k * m);
        let b_t = randv(&mut rng, n * k);
        let bias = randv(&mut rng, n);
        fold(&matmul(&a, &b, m, k, n));
        let mut atb = vec![0.0f32; m * n];
        matmul_at_b_acc(&mut atb, &a_t, &b, k, m, n);
        fold(&atb);
        let mut abt = vec![0.0f32; m * n];
        matmul_a_bt_acc(&mut abt, &a, &b_t, m, k, n);
        fold(&abt);
        let mut pre = vec![0.0f32; m * n];
        let mut act = vec![0.0f32; m * n];
        matmul_set_bias_gelu(&mut pre, &mut act, &a, &b, &bias, m, k, n);
        fold(&pre);
        fold(&act);
        let packed = kernels::PackedB::pack(&b, k, n);
        let mut c = vec![0.0f32; m * n];
        kernels::matmul_set_packed(&mut c, &a, &packed, m);
        fold(&c);
        // Fused multi-B entries (extra b operands so the three panels
        // differ).
        let b1 = randv(&mut rng, k * n);
        let b2 = randv(&mut rng, k * n);
        let mut m0 = vec![0.0f32; m * n];
        let mut m1 = vec![0.0f32; m * n];
        let mut m2 = vec![0.0f32; m * n];
        matmul_set_multi([&mut m0, &mut m1, &mut m2], &a, [&b, &b1, &b2], m, k, n);
        fold(&m0);
        fold(&m1);
        fold(&m2);
        let mut g0 = vec![0.0f32; m * n];
        let mut g1 = vec![0.0f32; m * n];
        let mut g2 = vec![0.0f32; m * n];
        matmul_at_b_acc_multi([&mut g0, &mut g1, &mut g2], &a_t, [&b, &b1, &b2], k, m, n);
        fold(&g0);
        fold(&g1);
        fold(&g2);
    }
    // Attention + LayerNorm (the lane-shaped kernels): one shape above the
    // parallel work cutoff and one ragged serial one.
    for (b, s, hh, hd) in [(4usize, 24usize, 4usize, 16usize), (2, 9, 3, 7)] {
        let d = hh * hd;
        let q = randv(&mut rng, b * s * d);
        let k = randv(&mut rng, b * s * d);
        let v = randv(&mut rng, b * s * d);
        let dctx = randv(&mut rng, b * s * d);
        let mut probs = vec![0.0f32; b * hh * s * s];
        let mut ctx = vec![0.0f32; b * s * d];
        attention_forward(b, s, hh, hd, &q, &k, &v, &mut probs, &mut ctx);
        fold(&probs);
        fold(&ctx);
        let mut dq = vec![0.0f32; b * s * d];
        let mut dk = vec![0.0f32; b * s * d];
        let mut dv = vec![0.0f32; b * s * d];
        attention_backward(b, s, hh, hd, &probs, &q, &k, &v, &dctx, &mut dq, &mut dk, &mut dv);
        fold(&dq);
        fold(&dk);
        fold(&dv);
        let mut step = vec![0.0f32; b * d];
        attention_decode_step(b, s, s - 1, hh, hd, &q[..b * d], &k, &v, &mut step);
        fold(&step);
        let lsc = randv(&mut rng, d);
        let lbs = randv(&mut rng, d);
        let (ln_y, ln_m, ln_i) = layernorm_stats(&q, &lsc, &lbs, b * s, d);
        fold(&ln_y);
        fold(&ln_m);
        fold(&ln_i);
    }
    h
}

/// Not an assertion by itself: prints the checksum marker the
/// cross-thread-count test below scrapes from a child process. Running it
/// standalone is harmless.
#[test]
fn helper_kernel_checksum_print() {
    let _g = serial_guard();
    set_force_serial(false);
    println!("KERNEL_CHECKSUM={:016x}", kernel_checksum());
}

#[test]
fn bit_identical_across_a3po_threads_1_vs_4() {
    let exe = std::env::current_exe().expect("test binary path");
    let run_child = |threads: &str| -> u64 {
        let out = std::process::Command::new(&exe)
            .args(["helper_kernel_checksum_print", "--exact", "--nocapture", "--test-threads=1"])
            .env("A3PO_THREADS", threads)
            .output()
            .expect("spawning checksum child");
        assert!(
            out.status.success(),
            "child (A3PO_THREADS={threads}) failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8_lossy(&out.stdout);
        stdout
            .lines()
            .find_map(|l| {
                l.trim()
                    .strip_prefix("KERNEL_CHECKSUM=")
                    .and_then(|hex| u64::from_str_radix(hex, 16).ok())
            })
            .unwrap_or_else(|| panic!("no KERNEL_CHECKSUM marker in child output:\n{stdout}"))
    };
    let c1 = run_child("1");
    let c4 = run_child("4");
    assert_eq!(c1, c4, "kernel results differ between A3PO_THREADS=1 and A3PO_THREADS=4");
    // And the ambient-threaded parent process agrees with both.
    let local = {
        let _g = serial_guard();
        set_force_serial(false);
        kernel_checksum()
    };
    assert_eq!(local, c1, "parent-process kernel results differ from A3PO_THREADS=1 child");
}

/// `A3PO_KERNEL` is read once per process, so the scalar-vs-default (and
/// explicit-simd) comparison re-runs this binary as children — mirroring
/// the `A3PO_THREADS` check above. On a host without AVX2 all three
/// children run the scalar tile and the check degenerates to a smoke test.
#[test]
fn bit_identical_across_kernel_paths() {
    let exe = std::env::current_exe().expect("test binary path");
    let run_child = |kernel: Option<&str>| -> u64 {
        let mut cmd = std::process::Command::new(&exe);
        cmd.args(["helper_kernel_checksum_print", "--exact", "--nocapture", "--test-threads=1"]);
        match kernel {
            // The parent may itself run under A3PO_KERNEL (the CI scalar
            // matrix), so the "default" child must clear it explicitly.
            None => cmd.env_remove("A3PO_KERNEL"),
            Some(v) => cmd.env("A3PO_KERNEL", v),
        };
        let out = cmd.output().expect("spawning checksum child");
        assert!(
            out.status.success(),
            "child (A3PO_KERNEL={kernel:?}) failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8_lossy(&out.stdout);
        stdout
            .lines()
            .find_map(|l| {
                l.trim()
                    .strip_prefix("KERNEL_CHECKSUM=")
                    .and_then(|hex| u64::from_str_radix(hex, 16).ok())
            })
            .unwrap_or_else(|| panic!("no KERNEL_CHECKSUM marker in child output:\n{stdout}"))
    };
    let scalar = run_child(Some("scalar"));
    let default = run_child(None);
    let simd = run_child(Some("simd"));
    assert_eq!(
        scalar, default,
        "kernel results differ between A3PO_KERNEL=scalar and the auto-detected tile"
    );
    assert_eq!(
        simd, default,
        "kernel results differ between A3PO_KERNEL=simd and the auto-detected tile"
    );
}
