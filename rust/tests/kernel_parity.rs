//! Property tests pinning the blocked GEMM kernels against a naive f64
//! reference, and the determinism contract: results are bit-identical
//! across `set_force_serial` on/off and scalar-vs-SIMD register tiles
//! in-process, and across `A3PO_THREADS=1` vs `A3PO_THREADS=4` and
//! `A3PO_KERNEL=scalar|simd` vs default out-of-process (the pool and the
//! ISA choice are both read once at startup, so the cross-process checks
//! re-run this test binary as a child with the variable set).

use std::sync::Mutex;

use a3po::runtime::native::kernels::{
    self, kernel_info, matmul, matmul_a_bt_acc, matmul_acc, matmul_at_b_acc, matmul_at_b_acc_multi,
    matmul_set, matmul_set_bias_gelu, matmul_set_multi, matmul_set_packed_multi, set_force_serial,
    set_kernel_override, KernelIsa,
};
use a3po::util::rng::Pcg64;

/// Serialises tests that toggle the process-global force-serial flag.
static SERIAL_GUARD: Mutex<()> = Mutex::new(());

fn serial_guard() -> std::sync::MutexGuard<'static, ()> {
    SERIAL_GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// Inputs scaled to ±0.25 keep f32 accumulation error well under the 1e-5
/// pin even at the largest k used here (the checks stay deterministic:
/// fixed seeds, fixed shapes).
fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 0.5 - 0.25).collect()
}

/// Random shapes with ragged tails in every dimension (not multiples of the
/// MR/NR/KC tiles), k values crossing the KC=256 block boundary, and both
/// sides of the small-GEMM cutoff.
fn shapes() -> Vec<(usize, usize, usize)> {
    let mut rng = Pcg64::from_seed(41);
    let mut out = vec![
        (1, 1, 1),
        (kernels::MR + 1, kernels::KC + 3, kernels::NR + 5),
        (2 * kernels::MR, 2 * kernels::KC, 2 * kernels::NR),
        (37, 300, 23),
        (64, 513, 31),
    ];
    for _ in 0..10 {
        out.push((
            1 + rng.below(40) as usize,
            1 + rng.below(400) as usize,
            1 + rng.below(48) as usize,
        ));
    }
    out
}

fn ref_ab(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
            c[i * n + j] = acc as f32;
        }
    }
    c
}

fn assert_close(got: &[f32], want: &[f32], what: &str, shape: (usize, usize, usize)) {
    assert_eq!(got.len(), want.len());
    for (idx, (x, y)) in got.iter().zip(want).enumerate() {
        assert!(
            (x - y).abs() <= 1e-5,
            "{what} {shape:?} diverges from naive reference at {idx}: {x} vs {y}"
        );
    }
}

#[test]
fn blocked_ab_matches_naive_reference() {
    let mut rng = Pcg64::from_seed(11);
    for (m, k, n) in shapes() {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let c = matmul(&a, &b, m, k, n);
        assert_close(&c, &ref_ab(&a, &b, m, k, n), "a·b", (m, k, n));
    }
}

#[test]
fn blocked_at_b_matches_naive_reference() {
    let mut rng = Pcg64::from_seed(12);
    for (m, k, n) in shapes() {
        // a is [k, m]; reference via explicit transpose.
        let a = randv(&mut rng, k * m);
        let b = randv(&mut rng, k * n);
        let mut c = vec![0.0f32; m * n];
        matmul_at_b_acc(&mut c, &a, &b, k, m, n);
        let mut at = vec![0.0f32; m * k];
        for p in 0..k {
            for i in 0..m {
                at[i * k + p] = a[p * m + i];
            }
        }
        assert_close(&c, &ref_ab(&at, &b, m, k, n), "aᵀ·b", (m, k, n));
    }
}

#[test]
fn blocked_a_bt_matches_naive_reference() {
    let mut rng = Pcg64::from_seed(13);
    for (m, k, n) in shapes() {
        // b is [n, k]; reference via explicit transpose.
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, n * k);
        let mut c = vec![0.0f32; m * n];
        matmul_a_bt_acc(&mut c, &a, &b, m, k, n);
        let mut bt = vec![0.0f32; k * n];
        for j in 0..n {
            for p in 0..k {
                bt[p * n + j] = b[j * k + p];
            }
        }
        assert_close(&c, &ref_ab(&a, &bt, m, k, n), "a·bᵀ", (m, k, n));
    }
}

#[test]
fn all_variants_bit_identical_serial_vs_threaded() {
    let _g = serial_guard();
    let mut rng = Pcg64::from_seed(14);
    for (m, k, n) in shapes() {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let a_t = randv(&mut rng, k * m);
        let b_t = randv(&mut rng, n * k);

        let mut results: Vec<Vec<Vec<f32>>> = Vec::new();
        for serial in [false, true] {
            set_force_serial(serial);
            let ab = matmul(&a, &b, m, k, n);
            let mut atb = vec![0.0f32; m * n];
            matmul_at_b_acc(&mut atb, &a_t, &b, k, m, n);
            let mut abt = vec![0.0f32; m * n];
            matmul_a_bt_acc(&mut abt, &a, &b_t, m, k, n);
            results.push(vec![ab, atb, abt]);
        }
        set_force_serial(false);
        for (v, name) in ["a·b", "aᵀ·b", "a·bᵀ"].iter().enumerate() {
            assert_eq!(
                results[0][v], results[1][v],
                "{name} at {:?} not bit-identical across force_serial",
                (m, k, n)
            );
        }
    }
}

#[test]
fn set_variant_bit_identical_to_acc_from_zero() {
    let mut rng = Pcg64::from_seed(15);
    for (m, k, n) in shapes() {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut c_set = vec![f32::NAN; m * n];
        matmul_set(&mut c_set, &a, &b, m, k, n);
        let mut c_acc = vec![0.0f32; m * n];
        matmul_acc(&mut c_acc, &a, &b, m, k, n);
        assert_eq!(c_set, c_acc, "set vs acc-from-zero at {:?}", (m, k, n));
    }
}

/// The tentpole invariant: the scalar and AVX2 register tiles produce
/// bit-identical results (no tolerance) over ragged shapes — `m % MR != 0`,
/// `n % NR != 0`, `k % KC != 0` — for every GEMM variant including the
/// fused bias+GELU epilogue and the packed entry.
#[test]
fn scalar_vs_simd_bit_identical_over_ragged_shapes() {
    let _g = serial_guard();
    if !kernel_info().simd_available {
        eprintln!("skipping scalar-vs-SIMD bit-equality: no AVX2 on this host");
        return;
    }
    let mut rng = Pcg64::from_seed(17);
    for (m, k, n) in shapes() {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let a_t = randv(&mut rng, k * m);
        let b_t = randv(&mut rng, n * k);
        let bias = randv(&mut rng, n);
        let packed = kernels::PackedB::pack(&b, k, n);

        let mut results: Vec<Vec<Vec<f32>>> = Vec::new();
        for isa in [KernelIsa::Scalar, KernelIsa::Avx2] {
            set_kernel_override(Some(isa));
            let ab = matmul(&a, &b, m, k, n);
            let mut atb = vec![0.0f32; m * n];
            matmul_at_b_acc(&mut atb, &a_t, &b, k, m, n);
            let mut abt = vec![0.0f32; m * n];
            matmul_a_bt_acc(&mut abt, &a, &b_t, m, k, n);
            let mut pre = vec![f32::NAN; m * n];
            let mut act = vec![f32::NAN; m * n];
            matmul_set_bias_gelu(&mut pre, &mut act, &a, &b, &bias, m, k, n);
            let mut pk = vec![f32::NAN; m * n];
            kernels::matmul_set_packed(&mut pk, &a, &packed, m);
            results.push(vec![ab, atb, abt, pre, act, pk]);
        }
        set_kernel_override(None);
        for (v, name) in ["a·b", "aᵀ·b", "a·bᵀ", "fused pre", "fused act", "packed"]
            .iter()
            .enumerate()
        {
            assert_eq!(
                results[0][v], results[1][v],
                "{name} at {:?} not bit-identical between scalar and SIMD tiles",
                (m, k, n)
            );
        }
    }
}

/// The fused multi-B entry points must be bit-identical to three separate
/// single-B calls over the same ragged shapes.
#[test]
fn multi_b_bit_identical_to_single_calls() {
    let mut rng = Pcg64::from_seed(18);
    for (m, k, n) in shapes() {
        let a = randv(&mut rng, m * k);
        let a_t = randv(&mut rng, k * m);
        let bs: Vec<Vec<f32>> = (0..3).map(|_| randv(&mut rng, k * n)).collect();

        let mut single: Vec<Vec<f32>> = (0..3).map(|_| vec![f32::NAN; m * n]).collect();
        for (c, b) in single.iter_mut().zip(bs.iter()) {
            matmul_set(c, &a, b, m, k, n);
        }
        let mut multi: Vec<Vec<f32>> = (0..3).map(|_| vec![f32::NAN; m * n]).collect();
        {
            let (c0, rest) = multi.split_first_mut().unwrap();
            let (c1, rest) = rest.split_first_mut().unwrap();
            let c2 = &mut rest[0];
            matmul_set_multi(
                [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                &a,
                [&bs[0], &bs[1], &bs[2]],
                m,
                k,
                n,
            );
        }
        assert_eq!(single, multi, "matmul_set_multi vs singles at {:?}", (m, k, n));

        let seed: Vec<Vec<f32>> = (0..3).map(|_| randv(&mut rng, m * n)).collect();
        let mut single_acc = seed.clone();
        for (c, b) in single_acc.iter_mut().zip(bs.iter()) {
            matmul_at_b_acc(c, &a_t, b, k, m, n);
        }
        let mut multi_acc = seed;
        {
            let (c0, rest) = multi_acc.split_first_mut().unwrap();
            let (c1, rest) = rest.split_first_mut().unwrap();
            let c2 = &mut rest[0];
            matmul_at_b_acc_multi(
                [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                &a_t,
                [&bs[0], &bs[1], &bs[2]],
                k,
                m,
                n,
            );
        }
        assert_eq!(single_acc, multi_acc, "matmul_at_b_acc_multi vs singles at {:?}", (m, k, n));

        let packed: Vec<kernels::PackedB> =
            bs.iter().map(|b| kernels::PackedB::pack(b, k, n)).collect();
        let mut multi_packed: Vec<Vec<f32>> = (0..3).map(|_| vec![f32::NAN; m * n]).collect();
        {
            let (c0, rest) = multi_packed.split_first_mut().unwrap();
            let (c1, rest) = rest.split_first_mut().unwrap();
            let c2 = &mut rest[0];
            matmul_set_packed_multi(
                [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                &a,
                [&packed[0], &packed[1], &packed[2]],
                m,
            );
        }
        assert_eq!(single, multi_packed, "matmul_set_packed_multi vs singles at {:?}", (m, k, n));
    }
}

// ---------------------------------------------------------------------------
// Cross-process bit-equality: the pool sizes itself from A3PO_THREADS once
// at first use, so different thread counts need separate processes.

/// FNV-1a over the raw bit patterns of every result the kernel suite
/// produces — any accumulation-order difference changes this value.
fn gemm_checksum() -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    let mut fold = |buf: &[f32]| {
        for &x in buf {
            h = (h ^ x.to_bits() as u64).wrapping_mul(FNV_PRIME);
        }
    };
    let mut rng = Pcg64::from_seed(16);
    // Shapes chosen to exercise the parallel path (above the ~128k
    // multiply-add serial cutoff) as well as ragged serial ops.
    for (m, k, n) in [(96, 128, 64), (256, 256, 64), (33, 300, 21), (5, 7, 3)] {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let a_t = randv(&mut rng, k * m);
        let b_t = randv(&mut rng, n * k);
        let bias = randv(&mut rng, n);
        fold(&matmul(&a, &b, m, k, n));
        let mut atb = vec![0.0f32; m * n];
        matmul_at_b_acc(&mut atb, &a_t, &b, k, m, n);
        fold(&atb);
        let mut abt = vec![0.0f32; m * n];
        matmul_a_bt_acc(&mut abt, &a, &b_t, m, k, n);
        fold(&abt);
        let mut pre = vec![0.0f32; m * n];
        let mut act = vec![0.0f32; m * n];
        matmul_set_bias_gelu(&mut pre, &mut act, &a, &b, &bias, m, k, n);
        fold(&pre);
        fold(&act);
        let packed = kernels::PackedB::pack(&b, k, n);
        let mut c = vec![0.0f32; m * n];
        kernels::matmul_set_packed(&mut c, &a, &packed, m);
        fold(&c);
        // Fused multi-B entries (extra b operands so the three panels
        // differ).
        let b1 = randv(&mut rng, k * n);
        let b2 = randv(&mut rng, k * n);
        let mut m0 = vec![0.0f32; m * n];
        let mut m1 = vec![0.0f32; m * n];
        let mut m2 = vec![0.0f32; m * n];
        matmul_set_multi([&mut m0, &mut m1, &mut m2], &a, [&b, &b1, &b2], m, k, n);
        fold(&m0);
        fold(&m1);
        fold(&m2);
        let mut g0 = vec![0.0f32; m * n];
        let mut g1 = vec![0.0f32; m * n];
        let mut g2 = vec![0.0f32; m * n];
        matmul_at_b_acc_multi([&mut g0, &mut g1, &mut g2], &a_t, [&b, &b1, &b2], k, m, n);
        fold(&g0);
        fold(&g1);
        fold(&g2);
    }
    h
}

/// Not an assertion by itself: prints the checksum marker the
/// cross-thread-count test below scrapes from a child process. Running it
/// standalone is harmless.
#[test]
fn helper_gemm_checksum_print() {
    let _g = serial_guard();
    set_force_serial(false);
    println!("GEMM_CHECKSUM={:016x}", gemm_checksum());
}

#[test]
fn bit_identical_across_a3po_threads_1_vs_4() {
    let exe = std::env::current_exe().expect("test binary path");
    let run_child = |threads: &str| -> u64 {
        let out = std::process::Command::new(&exe)
            .args(["helper_gemm_checksum_print", "--exact", "--nocapture", "--test-threads=1"])
            .env("A3PO_THREADS", threads)
            .output()
            .expect("spawning checksum child");
        assert!(
            out.status.success(),
            "child (A3PO_THREADS={threads}) failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8_lossy(&out.stdout);
        stdout
            .lines()
            .find_map(|l| {
                l.trim()
                    .strip_prefix("GEMM_CHECKSUM=")
                    .and_then(|hex| u64::from_str_radix(hex, 16).ok())
            })
            .unwrap_or_else(|| panic!("no GEMM_CHECKSUM marker in child output:\n{stdout}"))
    };
    let c1 = run_child("1");
    let c4 = run_child("4");
    assert_eq!(c1, c4, "GEMM results differ between A3PO_THREADS=1 and A3PO_THREADS=4");
    // And the ambient-threaded parent process agrees with both.
    let local = {
        let _g = serial_guard();
        set_force_serial(false);
        gemm_checksum()
    };
    assert_eq!(local, c1, "parent-process GEMM results differ from A3PO_THREADS=1 child");
}

/// `A3PO_KERNEL` is read once per process, so the scalar-vs-default (and
/// explicit-simd) comparison re-runs this binary as children — mirroring
/// the `A3PO_THREADS` check above. On a host without AVX2 all three
/// children run the scalar tile and the check degenerates to a smoke test.
#[test]
fn bit_identical_across_kernel_paths() {
    let exe = std::env::current_exe().expect("test binary path");
    let run_child = |kernel: Option<&str>| -> u64 {
        let mut cmd = std::process::Command::new(&exe);
        cmd.args(["helper_gemm_checksum_print", "--exact", "--nocapture", "--test-threads=1"]);
        match kernel {
            // The parent may itself run under A3PO_KERNEL (the CI scalar
            // matrix), so the "default" child must clear it explicitly.
            None => cmd.env_remove("A3PO_KERNEL"),
            Some(v) => cmd.env("A3PO_KERNEL", v),
        };
        let out = cmd.output().expect("spawning checksum child");
        assert!(
            out.status.success(),
            "child (A3PO_KERNEL={kernel:?}) failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
        let stdout = String::from_utf8_lossy(&out.stdout);
        stdout
            .lines()
            .find_map(|l| {
                l.trim()
                    .strip_prefix("GEMM_CHECKSUM=")
                    .and_then(|hex| u64::from_str_radix(hex, 16).ok())
            })
            .unwrap_or_else(|| panic!("no GEMM_CHECKSUM marker in child output:\n{stdout}"))
    };
    let scalar = run_child(Some("scalar"));
    let default = run_child(None);
    let simd = run_child(Some("simd"));
    assert_eq!(
        scalar, default,
        "GEMM results differ between A3PO_KERNEL=scalar and the auto-detected tile"
    );
    assert_eq!(
        simd, default,
        "GEMM results differ between A3PO_KERNEL=simd and the auto-detected tile"
    );
}
