//! Decode parity: the KV-cache session path must reproduce the full-forward
//! decode executable's logits within 1e-4 at every generated position — the
//! correctness anchor for the incremental decode subsystem. Covered across
//! random prompts, staggered EOS (rows retained mid-generation), threaded
//! vs single-thread kernels, and end-to-end through the rollout engine.
//!
//! Runs hermetically on the native `tiny` preset.

use std::sync::Arc;

use a3po::env::Problem;
use a3po::rollout::generate_for_problems;
use a3po::runtime::native::kernels;
use a3po::runtime::{Decoder, ParamSnapshot, PresetConfig, Runtime};
use a3po::sampler::SamplerConfig;
use a3po::util::rng::Pcg64;

const TOL: f32 = 1e-4;

fn fixture() -> (Runtime, PresetConfig, Arc<ParamSnapshot>) {
    std::env::set_var("A3PO_QUIET", "1");
    let rt = Runtime::native("tiny", Some(&["init", "decode"])).unwrap();
    let geo = rt.manifest.preset.clone();
    let snapshot = rt.init_params(3).unwrap();
    (rt, geo, snapshot)
}

/// Deterministic non-EOS token (vocab ids 0..2 are PAD/BOS/EOS).
fn safe_token(geo: &PresetConfig, row: usize, pos: usize) -> i32 {
    (3 + (row * 7 + pos * 11) % (geo.vocab - 3)) as i32
}

fn random_prompts(geo: &PresetConfig, seed: u64) -> Vec<i32> {
    let mut rng = Pcg64::from_seed(seed);
    (0..geo.rollout_batch * geo.prompt_len)
        .map(|_| rng.below(geo.vocab as u64) as i32)
        .collect()
}

fn assert_logits_close(pos: usize, session: &[f32], full: &[f32]) {
    assert_eq!(session.len(), full.len(), "logit count diverged at pos {pos}");
    for (i, (a, b)) in session.iter().zip(full).enumerate() {
        assert!(
            (a - b).abs() <= TOL,
            "pos {pos} logit {i}: session {a} vs full-forward {b}"
        );
    }
}

#[test]
fn session_logits_match_full_forward_every_position() {
    let (rt, geo, snapshot) = fixture();
    let decoder = rt.decoder().unwrap();
    assert!(decoder.incremental(), "native backend must provide KV sessions");
    let (br, pl, s) = (geo.rollout_batch, geo.prompt_len, geo.seq_len);
    let prompts = random_prompts(&geo, 7);

    let mut kv = decoder.start(&snapshot, &prompts, br, pl).unwrap();
    let mut ff = decoder.start_full_forward(&snapshot, &prompts, br, pl).unwrap();
    for pos in pl..s {
        assert_logits_close(pos, kv.logits(), ff.logits());
        if pos + 1 == s {
            break;
        }
        let toks: Vec<i32> = (0..br).map(|r| safe_token(&geo, r, pos)).collect();
        kv.step(&toks).unwrap();
        ff.step(&toks).unwrap();
    }
}

#[test]
fn session_parity_survives_mixed_finished_rows() {
    // Rows leave the batch at different positions (the EOS-staggered case);
    // the compacted KV caches must keep matching the full-forward reference.
    let (rt, geo, snapshot) = fixture();
    let decoder = rt.decoder().unwrap();
    let (br, pl, s) = (geo.rollout_batch, geo.prompt_len, geo.seq_len);
    assert!(br >= 4, "test wants a few rows to drop");
    let prompts = random_prompts(&geo, 21);

    let mut kv = decoder.start(&snapshot, &prompts, br, pl).unwrap();
    let mut ff = decoder.start_full_forward(&snapshot, &prompts, br, pl).unwrap();
    let mut active = br;
    for (step_i, pos) in (pl..s).enumerate() {
        assert_logits_close(pos, kv.logits(), ff.logits());
        if pos + 1 == s || active == 0 {
            break;
        }
        // Drop one row every other step, varying which index goes.
        if step_i % 2 == 1 && active > 1 {
            let victim = step_i % active;
            let keep: Vec<bool> = (0..active).map(|i| i != victim).collect();
            kv.retain_rows(&keep).unwrap();
            ff.retain_rows(&keep).unwrap();
            active -= 1;
        }
        let toks: Vec<i32> = (0..active).map(|r| safe_token(&geo, r, pos)).collect();
        kv.step(&toks).unwrap();
        ff.step(&toks).unwrap();
        assert_eq!(kv.active_rows(), active);
        assert_eq!(ff.active_rows(), active);
    }
}

#[test]
fn session_parity_is_thread_invariant() {
    // Threaded and single-thread kernels must produce identical logits
    // (the pool splits by rows without changing accumulation order).
    let (rt, geo, snapshot) = fixture();
    let decoder = rt.decoder().unwrap();
    let (br, pl, s) = (geo.rollout_batch, geo.prompt_len, geo.seq_len);
    let prompts = random_prompts(&geo, 40);

    let run = |serial: bool| -> Vec<f32> {
        kernels::set_force_serial(serial);
        let mut kv = decoder.start(&snapshot, &prompts, br, pl).unwrap();
        let mut all = Vec::new();
        for pos in pl..s {
            all.extend_from_slice(kv.logits());
            if pos + 1 == s {
                break;
            }
            let toks: Vec<i32> = (0..br).map(|r| safe_token(&geo, r, pos)).collect();
            kv.step(&toks).unwrap();
        }
        kernels::set_force_serial(false);
        all
    };
    let threaded = run(false);
    let serial = run(true);
    assert_eq!(threaded, serial, "threading changed decode results");
}

#[test]
fn generation_is_decode_path_invariant() {
    // Same RNG + matching logits => the rollout engine must produce
    // identical episodes through KV sessions and the full-forward fallback.
    let (rt, geo, snapshot) = fixture();
    let decoder = rt.decoder().unwrap();
    let problems: Vec<Problem> = (0..geo.rollout_batch)
        .map(|i| Problem { prompt: format!("{}+{}=", i % 7, (i * 3) % 5), answer: "0".into() })
        .collect();
    let generate = |d: &Decoder| {
        let mut rng = Pcg64::from_seed(11);
        generate_for_problems(d, &snapshot, &problems, &geo, &SamplerConfig::default(), &mut rng)
            .unwrap()
    };
    let via_sessions = generate(&decoder);
    let via_full_forward = generate(&decoder.without_sessions());

    assert_eq!(via_sessions.len(), via_full_forward.len());
    for (a, b) in via_sessions.iter().zip(&via_full_forward) {
        assert_eq!(a.tokens, b.tokens, "sampled tokens diverged between decode paths");
        assert_eq!(a.mask, b.mask);
        assert_eq!(a.text, b.text);
        assert_eq!(a.reward, b.reward);
        for (x, y) in a.behav_logp.iter().zip(&b.behav_logp) {
            assert!((x - y).abs() <= TOL, "behaviour logp diverged: {x} vs {y}");
        }
    }
}
