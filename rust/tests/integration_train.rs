//! End-to-end integration: full training runs (tiny preset) through the
//! public coordinator API, one per method, checking the paper's structural
//! invariants — sync stays on-policy, async accumulates staleness, A-3PO's
//! alpha follows Eq. 4, rewards/metrics stay finite, and the loglinear prox
//! phase is orders of magnitude cheaper than recompute's.
//!
//! Runs hermetically on the native backend: the artifacts directory below
//! does not exist, so `Runtime::load` resolves the built-in `tiny` preset.

use std::path::Path;

use a3po::config::{Method, RunOptions, StalenessPolicy};
use a3po::coordinator::{self, RunOutput};

fn opts(method: Method, steps: u64) -> RunOptions {
    std::env::set_var("A3PO_QUIET", "1");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    RunOptions {
        preset: "tiny".into(),
        artifacts_dir: dir.to_str().unwrap().into(),
        out_dir: std::env::temp_dir()
            .join(format!("a3po-itest-{}", std::process::id()))
            .to_str()
            .unwrap()
            .into(),
        method,
        steps,
        pretrain_steps: 8,
        workers: 2,
        eval_every: 0,
        eval_prompts: 16,
        seed: 42,
        staleness: StalenessPolicy { max_staleness: 16, max_buffered: 128 },
        ..Default::default()
    }
}

fn run(method: Method, steps: u64) -> RunOutput {
    coordinator::run(&opts(method, steps)).expect("run failed")
}

#[test]
fn sync_run_is_on_policy() {
    let out = run(Method::Sync, 4);
    assert_eq!(out.logger.steps.len(), 4);
    for s in &out.logger.steps {
        assert_eq!(s.mean_staleness, 0.0, "sync data must be fresh");
        assert_eq!(s.mean_alpha, 0.0);
        assert!(s.rollout_secs > 0.0, "sync generates inline");
        assert!(s.train.loss.is_finite());
        // On-policy + coupled loss: importance weights are exactly 1 on the
        // first minibatch and the metric maxes over the step stay near 1.
        assert!(s.train.max_is_weight < 3.0, "iw {}", s.train.max_is_weight);
    }
}

#[test]
fn loglinear_run_accumulates_staleness_and_alpha_follows_eq4() {
    let out = run(Method::Loglinear, 6);
    assert_eq!(out.logger.steps.len(), 6);
    let late = &out.logger.steps[3..];
    assert!(
        late.iter().any(|s| s.mean_staleness > 0.0),
        "async training should see stale data"
    );
    for s in &out.logger.steps {
        // per-batch mean alpha is within the Eq. 4 envelope
        assert!((0.0..=1.0).contains(&s.mean_alpha), "alpha {}", s.mean_alpha);
        if s.mean_staleness == 0.0 {
            assert_eq!(s.mean_alpha, 0.0);
        }
        // A-3PO's prox phase is an elementwise op: sub-millisecond.
        assert!(s.prox_secs < 0.01, "loglinear prox {}s", s.prox_secs);
    }
}

#[test]
fn recompute_pays_for_prox_forward_and_loglinear_does_not() {
    let rec = run(Method::Recompute, 3);
    let log = run(Method::Loglinear, 3);
    let rec_prox = rec.phases.mean("prox");
    let log_prox = log.phases.mean("prox");
    // The paper's Fig. 1 gap: the extra forward pass vs the Eq. 3
    // elementwise interpolation must differ by at least an order of
    // magnitude per step (>= 3,000x on the paper's testbed).
    assert!(
        rec_prox > 10.0 * log_prox,
        "recompute prox {rec_prox}s should dwarf loglinear {log_prox}s"
    );
    assert!(rec_prox > 0.0, "recompute prox phase must actually run a forward pass");
    // Both produce finite, comparable training metrics.
    for out in [&rec, &log] {
        for s in &out.logger.steps {
            assert!(s.train.loss.is_finite());
            assert!(s.train.entropy > 0.0);
            assert!(s.train.min_is_weight <= s.train.max_is_weight);
        }
    }
}

#[test]
fn final_eval_and_summary_are_reported() {
    let o = opts(Method::Loglinear, 2);
    let out = coordinator::run(&o).unwrap();
    assert!((0.0..=1.0).contains(&out.final_eval));
    let j = out.summary_json(&o);
    assert_eq!(j.get("method").as_str(), Some("loglinear"));
    assert_eq!(j.get("steps").as_f64(), Some(2.0));
    assert!(j.get("total_seconds").as_f64().unwrap() > 0.0);
    // Metrics JSONL landed on disk.
    let path = Path::new(&o.out_dir).join("tiny_loglinear.jsonl");
    let text = std::fs::read_to_string(path).unwrap();
    assert!(text.lines().count() >= 3); // 2 steps + final eval
}

#[test]
fn checkpoint_save_then_benchmark_eval() {
    let o = opts(Method::Loglinear, 2);
    let out = coordinator::run(&o).unwrap();
    let base = coordinator::save_checkpoint(&o, &out).unwrap();
    let loaded =
        a3po::runtime::checkpoint::load(&base, &out.runtime.manifest).unwrap();
    assert_eq!(loaded.version, out.final_snapshot.version);

    // Evaluate the loaded checkpoint on a fitting slice of the MATH-like
    // suite (tiny's window only fits short prompts).
    let geo = &out.runtime.manifest.preset;
    let suite = a3po::env::suites::math_like();
    let fit = a3po::env::suites::fitting(&suite, geo.prompt_len - 1, geo.gen_len - 1);
    assert!(!fit.problems.is_empty());
    let take: Vec<_> = fit.problems.into_iter().take(geo.rollout_batch).collect();
    let decoder = out.runtime.decoder().unwrap();
    let (p, se) =
        coordinator::eval::evaluate_pass_at_1(&decoder, &loaded, &take, geo, true).unwrap();
    assert!((0.0..=1.0).contains(&p));
    assert!(se >= 0.0);
}

#[test]
fn injected_staleness_drives_alpha() {
    let mut o = opts(Method::Loglinear, 2);
    o.inject_staleness = 4;
    let out = coordinator::run(&o).unwrap();
    for s in &out.logger.steps {
        assert!(s.mean_staleness >= 4.0);
        // alpha = 1/d <= 1/4 for every sequence.
        assert!(s.mean_alpha <= 0.25 + 1e-6, "alpha {}", s.mean_alpha);
    }
}
