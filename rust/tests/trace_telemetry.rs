//! Tracing + telemetry integration: the span recorder's global state, the
//! Chrome-trace export, and the coordinator's end-to-end telemetry report.
//!
//! The trace recorder is process-wide (one enabled flag, one registry), so
//! every test that records serialises on [`TRACE_LOCK`] — the pure
//! export-format tests live as unit tests in `trace/mod.rs` instead.
//!
//! Runs hermetically on the native backend (no artifacts on disk).

use std::path::Path;
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use a3po::config::{Method, RunOptions, StalenessPolicy};
use a3po::coordinator;
use a3po::trace;
use a3po::util::json::Json;

static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    // A poisoned lock just means another trace test failed; the global
    // recorder state is still usable.
    TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn span_events(trace: &Json) -> Vec<&Json> {
    trace
        .get("traceEvents")
        .as_arr()
        .expect("traceEvents array")
        .iter()
        .filter(|e| e.get("ph").as_str() == Some("X"))
        .collect()
}

#[test]
fn span_nesting_survives_chrome_roundtrip() {
    let _g = lock();
    trace::start();
    {
        let _outer = trace::span_arg("outer", "test", "step", 7.0);
        std::thread::sleep(Duration::from_millis(2));
        {
            let _inner = trace::span("inner", "test");
            std::thread::sleep(Duration::from_millis(2));
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let data = trace::stop();

    let dir = std::env::temp_dir().join(format!("a3po-trace-rt-{}", std::process::id()));
    let path = dir.join("nested.json");
    data.write_chrome(&path).unwrap();
    let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    std::fs::remove_dir_all(&dir).ok();

    let spans = span_events(&parsed);
    let find = |name: &str| {
        *spans.iter().find(|e| e.get("name").as_str() == Some(name)).unwrap_or_else(|| {
            panic!("span {name:?} missing from exported trace");
        })
    };
    let (outer, inner) = (find("outer"), find("inner"));
    let iv = |e: &Json| {
        let ts = e.get("ts").as_f64().unwrap();
        (ts, ts + e.get("dur").as_f64().unwrap())
    };
    let ((os, oe), (is_, ie)) = (iv(outer), iv(inner));
    assert!(os <= is_ && ie <= oe, "inner [{is_},{ie}] must nest in outer [{os},{oe}]");
    assert!(ie - is_ >= 1_000.0, "inner slept 2ms, dur {}us", ie - is_);
    assert_eq!(outer.get("tid").as_f64(), inner.get("tid").as_f64(), "same recording thread");
    assert_eq!(outer.get("args").get("step").as_f64(), Some(7.0));
}

#[test]
fn multi_thread_buffers_flush_on_exit() {
    let _g = lock();
    trace::start();
    let handles: Vec<_> = (0..4)
        .map(|w| {
            std::thread::Builder::new()
                .name(format!("recorder-{w}"))
                .spawn(move || {
                    for _ in 0..100 {
                        let t = trace::now_us();
                        trace::complete_span("tick", "test", t, t + 1.0, None);
                    }
                })
                .unwrap()
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    drop(trace::span("main_span", "test"));
    let data = trace::stop();

    assert_eq!(data.spans().count(), 401, "4x100 thread spans + 1 main span");
    assert!(
        data.span_tids().len() >= 5,
        "expected >=5 distinct recording threads, got {:?}",
        data.span_tids()
    );
    let names: Vec<&str> = data.threads.iter().map(|(_, n)| n.as_str()).collect();
    assert!(names.iter().any(|n| n.starts_with("recorder-")), "thread names registered");
}

#[test]
fn disabled_recorder_is_a_no_op() {
    let _g = lock();
    assert!(!trace::enabled());
    // None of these may record (or panic) while tracing is off.
    drop(trace::span("ghost", "test"));
    drop(trace::span_arg("ghost", "test", "k", 1.0));
    trace::counter("ghost_counter", 3.0);
    trace::instant("ghost_instant", "test");
    let t = trace::now_us();
    trace::complete_span("ghost_complete", "test", t, t + 5.0, None);

    trace::start();
    let data = trace::stop();
    assert!(data.events.is_empty(), "disabled-mode events leaked: {:?}", data.events);
}

#[test]
fn spans_open_across_stop_are_discarded() {
    let _g = lock();
    trace::start();
    let open = trace::span("straddler", "test");
    let data = trace::stop();
    drop(open); // closes after stop: must not bleed into a later window
    assert!(data.spans().all(|e| e.name != "straddler"));
    trace::start();
    let later = trace::stop();
    assert!(later.events.is_empty(), "straddler leaked into next window");
}

#[test]
fn traced_async_run_reports_consistent_telemetry() {
    let _g = lock();
    std::env::set_var("A3PO_QUIET", "1");
    let dir = std::env::temp_dir().join(format!("a3po-trace-smoke-{}", std::process::id()));
    let trace_path = dir.join("trace_loglinear.json");
    // Points at a nonexistent artifacts dir so the built-in tiny preset is
    // used (same hermetic setup as integration_train.rs).
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let opts = RunOptions {
        preset: "tiny".into(),
        artifacts_dir: artifacts.to_str().unwrap().into(),
        out_dir: dir.to_str().unwrap().into(),
        method: Method::Loglinear,
        steps: 3,
        pretrain_steps: 0,
        workers: 2,
        eval_every: 0,
        eval_prompts: 8,
        seed: 7,
        staleness: StalenessPolicy { max_staleness: 16, max_buffered: 128 },
        trace_path: Some(trace_path.to_str().unwrap().into()),
        ..Default::default()
    };
    let out = coordinator::run(&opts).expect("traced run failed");

    // -- telemetry report ------------------------------------------------
    let tel = &out.telemetry;
    assert!(
        tel.buffer.accounting_consistent(),
        "pushed {} != popped {} + dropped {} + remaining {}",
        tel.buffer.pushed_groups,
        tel.buffer.popped_groups,
        tel.buffer.dropped_stale_groups,
        tel.buffer.remaining_groups
    );
    assert_eq!(tel.buffer.popped_groups, 3 * 4, "3 steps x 4 groups (tiny train_batch/G)");
    assert!(tel.buffer.high_water_episodes > 0);
    assert!(!tel.buffer.occupancy.is_empty());
    assert_eq!(tel.staleness.n(), 3 * 16, "one staleness sample per trained row");
    assert_eq!(tel.workers.len(), 2);
    for w in &tel.workers {
        assert!(w.total_secs > 0.0);
        assert!((0.0..=1.0).contains(&w.utilisation()));
    }
    assert!((0.0..=1.0).contains(&tel.trainer_starvation_frac()));
    // The trainer's measured wait envelope contains the buffer's blocked
    // condvar time (the wait phase wraps the pop_groups call).
    assert!(tel.buffer.pop_wait_secs <= tel.trainer_wait_secs + 0.05);

    // -- step records: wait vs rollout semantics -------------------------
    for s in &out.logger.steps {
        assert_eq!(s.rollout_secs, 0.0, "async trainer never generates inline");
        assert!(s.wait_secs >= 0.0);
        assert!(s.staleness_p50 <= s.staleness_p95);
        assert!(s.staleness_p95 <= s.staleness_max);
    }

    // -- summary carries the new fields ----------------------------------
    let summary = out.summary_json(&opts);
    assert!(summary.get("trainer_starvation_frac").as_f64().is_some());
    assert!(summary.get("staleness_p95").as_f64().is_some());

    // -- JSONL schema ----------------------------------------------------
    let jsonl = std::fs::read_to_string(dir.join("tiny_loglinear.jsonl")).unwrap();
    let first_step = jsonl
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .find(|j| j.get("kind").as_str() == Some("step"))
        .unwrap();
    assert!(first_step.get("wait_secs").as_f64().is_some());
    assert_eq!(first_step.get("rollout_secs").as_f64(), Some(0.0));
    assert!(first_step.get("staleness_max").as_f64().is_some());

    // -- exported Chrome trace -------------------------------------------
    let trace_json = Json::parse(&std::fs::read_to_string(&trace_path).unwrap())
        .expect("trace file must be valid JSON");
    let spans = span_events(&trace_json);
    assert!(spans.iter().any(|e| e.get("name").as_str() == Some("step")), "trainer step spans");
    assert!(
        spans.iter().any(|e| e.get("name").as_str() == Some("pop_groups")),
        "trainer buffer-wait spans"
    );
    let gen_tids: std::collections::BTreeSet<i64> = spans
        .iter()
        .filter(|e| e.get("name").as_str() == Some("generate"))
        .filter_map(|e| e.get("tid").as_i64())
        .collect();
    assert!(gen_tids.len() >= 2, "both rollout workers must record generate spans: {gen_tids:?}");
    let all_tids: std::collections::BTreeSet<i64> =
        spans.iter().filter_map(|e| e.get("tid").as_i64()).collect();
    assert!(all_tids.len() >= 3, "trainer + 2 workers, got tids {all_tids:?}");
    let thread_names: Vec<&str> = trace_json
        .get("traceEvents")
        .as_arr()
        .unwrap()
        .iter()
        .filter(|e| e.get("ph").as_str() == Some("M"))
        .filter_map(|e| e.get("args").get("name").as_str())
        .collect();
    assert!(
        thread_names.iter().filter(|n| n.starts_with("rollout-")).count() >= 2,
        "worker lanes labelled: {thread_names:?}"
    );

    std::fs::remove_dir_all(&dir).ok();
}
