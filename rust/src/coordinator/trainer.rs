//! The trainer: drives the method-specific training path, times the
//! proximal-policy phase (Fig. 1), and publishes new weight versions.
//!
//! Two data paths, chosen at construction:
//!
//! * **Session** — the backend's [`TrainSession`] owns parameters, Adam
//!   moments, and the step counter in-place; a step moves only the batch in
//!   and metrics + θ log-probs out, plus one copy-on-publish parameter
//!   snapshot for the [`WeightStore`].
//! * **Legacy (positional)** — for backends without session support (PJRT):
//!   the trainer keeps the optimiser state as host tensors and round-trips
//!   all of it through the positional `train_*`/`pretrain` executables,
//!   unpacking outputs by spec name via [`TrainOutputs`].
//!
//! Method-specific prox phase, mirroring the paper exactly:
//! * `sync`       — no proximal policy at all (coupled loss).
//! * `recompute`  — an extra full forward pass (`prox_forward` executable)
//!   over the training batch at step start; the result is frozen across the
//!   step's minibatch updates. This is the 4–8 s/step cost in Fig. 1.
//! * `loglinear`  — A-3PO: α-weighted log-linear interpolation (Eq. 3). The
//!   interpolation itself is fused into the train executable (which has the
//!   real θ log-probs in hand); the timed phase here is the standalone
//!   elementwise op over the θ log-probs the backend returned on the
//!   previous step, matching how the paper reports its ~1 ms "loglinear"
//!   bar.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::Method;
use crate::metrics::TrainMetrics;
use crate::runtime::{
    Executable, HostTensor, ParamSnapshot, Runtime, TrainInputs, TrainOutputs, TrainSession,
    TrainState, WeightStore,
};
use crate::trace;
use crate::util::timer::Stopwatch;

use super::batch::TrainBatch;

/// The positional fallback: optimiser state lives host-side and crosses the
/// backend boundary in full on every step.
struct LegacyPath {
    train_exec: Arc<Executable>,
    pretrain_exec: Option<Arc<Executable>>,
    adam_m: Vec<HostTensor>,
    adam_v: Vec<HostTensor>,
    /// Adam step counter, kept in lockstep with the executable's reported
    /// `step` output (bias correction).
    opt_step: i32,
    n_params: usize,
}

enum TrainPath {
    Session(Box<dyn TrainSession>),
    Legacy(LegacyPath),
}

pub struct Trainer {
    method: Method,
    path: TrainPath,
    prox_exec: Option<Arc<Executable>>,
    store: Arc<WeightStore>,
    /// Latest published parameters (shared snapshot; publishing is an Arc
    /// swap). Under sessions this mirrors the session's in-place state at
    /// step boundaries.
    snapshot: Arc<ParamSnapshot>,
    /// θ log-probs returned by the previous train step (native backend);
    /// operand of the standalone Eq. 3 measurement.
    last_theta_logp: Option<Vec<f32>>,
    geo_b: usize,
    geo_s: usize,
}

/// Timing breakdown of one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepTiming {
    pub prox_secs: f64,
    pub train_secs: f64,
}

impl Trainer {
    /// Build a trainer, preferring the backend's train sessions when it has
    /// them; otherwise the positional executables.
    pub fn new(
        runtime: &Runtime,
        method: Method,
        initial: Arc<ParamSnapshot>,
        store: Arc<WeightStore>,
    ) -> Result<Trainer> {
        Trainer::build(runtime, method, initial, store, true)
    }

    /// Build a trainer pinned to the positional path even when the backend
    /// has sessions (parity tests and benchmarks).
    pub fn new_without_sessions(
        runtime: &Runtime,
        method: Method,
        initial: Arc<ParamSnapshot>,
        store: Arc<WeightStore>,
    ) -> Result<Trainer> {
        Trainer::build(runtime, method, initial, store, false)
    }

    fn build(
        runtime: &Runtime,
        method: Method,
        initial: Arc<ParamSnapshot>,
        store: Arc<WeightStore>,
        use_sessions: bool,
    ) -> Result<Trainer> {
        let n_params = runtime.manifest.n_params();
        if initial.params.len() != n_params {
            bail!("snapshot has {} tensors, manifest {}", initial.params.len(), n_params);
        }
        let prox_exec = if method == Method::Recompute {
            Some(runtime.exec("prox_forward")?.clone())
        } else {
            None
        };
        let path = match runtime.train_session_factory().filter(|_| use_sessions) {
            Some(factory) => TrainPath::Session(factory.start(method.executable(), &initial)?),
            None => TrainPath::Legacy(LegacyPath {
                train_exec: runtime.exec(method.executable())?.clone(),
                pretrain_exec: if runtime.has_exec("pretrain") {
                    Some(runtime.exec("pretrain")?.clone())
                } else {
                    None
                },
                adam_m: runtime.zero_adam_state(),
                adam_v: runtime.zero_adam_state(),
                opt_step: 0,
                n_params,
            }),
        };
        Ok(Trainer {
            method,
            path,
            prox_exec,
            store,
            snapshot: initial,
            last_theta_logp: None,
            geo_b: runtime.manifest.preset.train_batch,
            geo_s: runtime.manifest.preset.seq_len,
        })
    }

    pub fn version(&self) -> u64 {
        self.snapshot.version
    }

    pub fn snapshot(&self) -> Arc<ParamSnapshot> {
        self.snapshot.clone()
    }

    /// Whether this trainer drives a stateful backend session (vs the
    /// positional executables).
    pub fn session_active(&self) -> bool {
        matches!(self.path, TrainPath::Session(_))
    }

    /// Short label of the active data path for logs/summaries.
    pub fn path_label(&self) -> &'static str {
        match self.path {
            TrainPath::Session(_) => "session",
            TrainPath::Legacy(_) => "positional",
        }
    }

    /// Optimiser steps applied so far (across pretrain + RL minibatches).
    pub fn opt_step(&self) -> i32 {
        match &self.path {
            TrainPath::Session(s) => s.opt_step(),
            TrainPath::Legacy(l) => l.opt_step,
        }
    }

    /// Export the full optimiser state (params + Adam moments + step) for
    /// checkpointing, from whichever path holds it.
    pub fn export_state(&self) -> Result<TrainState> {
        match &self.path {
            TrainPath::Session(s) => s.export_state(),
            TrainPath::Legacy(l) => Ok(TrainState {
                opt_step: l.opt_step,
                params: self.snapshot.params.clone(),
                adam_m: l.adam_m.clone(),
                adam_v: l.adam_v.clone(),
            }),
        }
    }

    /// One RL training step (= n_minibatch gradient updates inside the
    /// backend), with the method's prox phase timed separately. Takes the
    /// batch by value: the session path borrows it, the legacy path moves
    /// its buffers into the executable inputs — neither copies.
    pub fn step(&mut self, batch: TrainBatch) -> Result<(TrainMetrics, StepTiming)> {
        let (b, s) = (self.geo_b, self.geo_s);
        let t = s - 1;

        // --- proximal-policy phase (the paper's Fig. 1 measurement) ------
        let prox_sw = Stopwatch::start();
        let prox_span = trace::span("prox", "trainer");
        let prox_host: Option<Vec<f32>> = match self.method {
            Method::Recompute => {
                // Extra forward pass over the training batch; frozen for
                // the rest of the step.
                let exec = self.prox_exec.as_ref().expect("recompute needs prox_forward");
                let tokens = HostTensor::i32(vec![b, s], batch.tokens.clone());
                let mut refs = self.snapshot.tensor_refs();
                refs.push(&tokens);
                let outs = exec.run_refs(&refs)?;
                match outs.into_iter().next() {
                    Some(HostTensor::F32 { data, .. }) => Some(data),
                    _ => bail!("prox_forward returned no f32 output"),
                }
            }
            Method::Loglinear => {
                // Eq. 3 as a standalone elementwise op (what replaces the
                // forward pass). θ log-probs come from the previous step's
                // train output; on the very first step (no θ yet) the
                // anchor degenerates to the behaviour policy, exactly the
                // d = 0 on-policy case. The train executable re-fuses the
                // interpolation with its own fresh θ, so this is
                // measurement, not double work.
                let theta: &[f32] = match &self.last_theta_logp {
                    Some(v) => v,
                    None => &batch.behav_logp,
                };
                Some(interp_prox_host(theta, &batch.behav_logp, &batch.alpha, t))
            }
            // Coupled loss: no proximal policy at all.
            Method::Sync => None,
        };
        drop(prox_span);
        let prox_secs = prox_sw.secs();

        // --- train step ---------------------------------------------------
        let train_sw = Stopwatch::start();
        let train_span = trace::span("train", "trainer");
        let (metrics_vec, theta_logp, new_params) = match &mut self.path {
            TrainPath::Session(session) => {
                let inputs = TrainInputs {
                    tokens: &batch.tokens,
                    mask: &batch.mask,
                    behav_logp: &batch.behav_logp,
                    adv: &batch.adv,
                    alpha: &batch.alpha,
                    prox_logp: prox_host.as_deref(),
                };
                let out = session.train_step(&inputs)?;
                // The one per-step parameter copy: copy-on-publish.
                let params = session.snapshot_params()?;
                (out.metrics, out.theta_logp, params)
            }
            TrainPath::Legacy(l) => {
                let TrainBatch { tokens, mask, behav_logp, adv, alpha, .. } = batch;
                let tokens = HostTensor::i32(vec![b, s], tokens);
                let mask = HostTensor::f32(vec![b, t], mask);
                let behav = HostTensor::f32(vec![b, t], behav_logp);
                let adv = HostTensor::f32(vec![b, t], adv);
                let alpha = HostTensor::f32(vec![b], alpha);
                // The positional signature always takes a prox input; sync
                // passes a zero placeholder the executable ignores.
                let prox =
                    HostTensor::f32(vec![b, t], prox_host.unwrap_or_else(|| vec![0.0; b * t]));
                let step_lit = HostTensor::scalar_i32(l.opt_step);
                let mut refs = self.snapshot.tensor_refs();
                refs.extend(l.adam_m.iter());
                refs.extend(l.adam_v.iter());
                refs.push(&step_lit);
                refs.push(&tokens);
                refs.push(&mask);
                refs.push(&behav);
                refs.push(&adv);
                refs.push(&alpha);
                refs.push(&prox);
                let outs = l.train_exec.run_refs(&refs)?;
                let unpacked = TrainOutputs::unpack(&l.train_exec.spec, outs, l.n_params)?;
                l.adam_m = unpacked.adam_m;
                l.adam_v = unpacked.adam_v;
                l.opt_step = unpacked.opt_step;
                let theta = match unpacked.theta_logp {
                    Some(HostTensor::F32 { data, .. }) => Some(data),
                    Some(_) => bail!("theta_logp output must be f32"),
                    None => None,
                };
                (unpacked.metrics.as_f32()?.to_vec(), theta, unpacked.params)
            }
        };
        drop(train_span);
        let train_secs = train_sw.secs();

        if let Some(theta) = theta_logp {
            self.last_theta_logp = Some(theta);
        }
        let _publish_span = trace::span("publish", "trainer");
        let new_version = self.snapshot.version + 1;
        self.snapshot = ParamSnapshot::new(new_version, new_params);
        self.store.publish(self.snapshot.clone());

        let metrics = TrainMetrics::from_vector(&metrics_vec);
        Ok((metrics, StepTiming { prox_secs, train_secs }))
    }

    /// One supervised warm-start step (next-token CE on correct solutions).
    pub fn pretrain_step(&mut self, tokens: &[i32], mask: &[f32]) -> Result<TrainMetrics> {
        let (b, s) = (self.geo_b, self.geo_s);
        let t = s - 1;
        if tokens.len() != b * s {
            bail!("pretrain tokens: {} elements, expected [{b}, {s}]", tokens.len());
        }
        if mask.len() != b * t {
            bail!("pretrain mask: {} elements, expected [{b}, {t}]", mask.len());
        }
        let (metrics_vec, new_params) = match &mut self.path {
            TrainPath::Session(session) => {
                let out = session.pretrain_step(tokens, mask)?;
                (out.metrics, session.snapshot_params()?)
            }
            TrainPath::Legacy(l) => {
                let exec = match &l.pretrain_exec {
                    Some(e) => e.clone(),
                    None => bail!("pretrain executable not loaded"),
                };
                let tokens = HostTensor::i32(vec![b, s], tokens.to_vec());
                let mask = HostTensor::f32(vec![b, t], mask.to_vec());
                let step_lit = HostTensor::scalar_i32(l.opt_step);
                let mut refs = self.snapshot.tensor_refs();
                refs.extend(l.adam_m.iter());
                refs.extend(l.adam_v.iter());
                refs.push(&step_lit);
                refs.push(&tokens);
                refs.push(&mask);
                let outs = exec.run_refs(&refs)?;
                let unpacked = TrainOutputs::unpack(&exec.spec, outs, l.n_params)?;
                l.adam_m = unpacked.adam_m;
                l.adam_v = unpacked.adam_v;
                l.opt_step = unpacked.opt_step;
                (unpacked.metrics.as_f32()?.to_vec(), unpacked.params)
            }
        };
        // Warm start does not bump the RL version: v(pi) counts RL updates.
        self.snapshot = ParamSnapshot::new(self.snapshot.version, new_params);
        self.store.publish(self.snapshot.clone());
        Ok(TrainMetrics::from_vector(&metrics_vec))
    }
}

/// Eq. 3 on the host: `log π_prox = α·log π_behav + (1-α)·log π_θ`, with α
/// broadcast per sequence row. This is the op A-3PO substitutes for
/// recompute's full forward pass; the native train executables apply the
/// same formula (with their own fresh θ) inside the fused loss.
pub fn interp_prox_host(
    theta_logp: &[f32],
    behav_logp: &[f32],
    alpha: &[f32],
    t: usize,
) -> Vec<f32> {
    assert_eq!(theta_logp.len(), behav_logp.len(), "theta/behav length mismatch");
    assert_eq!(alpha.len() * t, behav_logp.len(), "alpha rows don't cover the batch");
    let mut out = Vec::with_capacity(behav_logp.len());
    for (row, &a) in alpha.iter().enumerate() {
        let base = row * t;
        for i in base..base + t {
            out.push(a * behav_logp[i] + (1.0 - a) * theta_logp[i]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_is_a_genuine_interpolation() {
        let theta = vec![-2.0f32, -4.0, -6.0, -8.0];
        let behav = vec![-1.0f32, -2.0, -3.0, -4.0];
        // Row 0: alpha = 0.5 -> midpoint; row 1: alpha = 0.25.
        let out = interp_prox_host(&theta, &behav, &[0.5, 0.25], 2);
        assert_eq!(out, vec![-1.5, -3.0, -5.25, -7.0]);
    }

    #[test]
    fn interp_alpha_extremes_select_an_operand() {
        let theta = vec![-2.0f32, -4.0, -6.0, -8.0];
        let behav = vec![-1.0f32, -2.0, -3.0, -4.0];
        // alpha = 0: anchor at theta (on-policy). alpha = 1: anchor at the
        // behaviour policy (fully stale).
        let out = interp_prox_host(&theta, &behav, &[0.0, 1.0], 2);
        assert_eq!(&out[..2], &theta[..2]);
        assert_eq!(&out[2..], &behav[2..]);
    }

    #[test]
    #[should_panic(expected = "alpha rows")]
    fn interp_rejects_mismatched_rows() {
        interp_prox_host(&[-1.0; 4], &[-1.0; 4], &[0.5; 3], 2);
    }
}
