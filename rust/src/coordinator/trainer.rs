//! The trainer: owns the optimiser state, drives the method-specific train
//! executable, times the proximal-policy phase (Fig. 1), and publishes new
//! weight versions.
//!
//! Method-specific prox phase, mirroring the paper exactly:
//! * `sync`       — no proximal policy at all (coupled loss).
//! * `recompute`  — an extra full forward pass (`prox_forward` executable)
//!   over the training batch at step start; the result is frozen across the
//!   step's minibatch updates. This is the 4–8 s/step cost in Fig. 1.
//! * `loglinear`  — A-3PO: α-weighted log-linear interpolation (Eq. 3). The
//!   interpolation itself is fused into the train executable (which has the
//!   real θ log-probs in hand); the timed phase here is the standalone
//!   elementwise op over the θ log-probs the backend returned on the
//!   previous step, matching how the paper reports its ~1 ms "loglinear"
//!   bar.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::Method;
use crate::metrics::TrainMetrics;
use crate::runtime::{Executable, HostTensor, ParamSnapshot, Runtime, WeightStore};
use crate::util::timer::Stopwatch;

use super::batch::TrainBatch;

pub struct Trainer {
    method: Method,
    train_exec: Arc<Executable>,
    prox_exec: Option<Arc<Executable>>,
    pretrain_exec: Option<Arc<Executable>>,
    store: Arc<WeightStore>,
    /// Current parameters (shared snapshot; publishing is an Arc swap).
    snapshot: Arc<ParamSnapshot>,
    adam_m: Vec<HostTensor>,
    adam_v: Vec<HostTensor>,
    /// Adam step counter fed to the executable (bias correction).
    opt_step: i32,
    /// θ log-probs returned by the previous train step (native backend);
    /// operand of the standalone Eq. 3 measurement.
    last_theta_logp: Option<Vec<f32>>,
    n_params: usize,
    n_minibatch: usize,
    geo_b: usize,
    geo_s: usize,
}

/// Timing breakdown of one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepTiming {
    pub prox_secs: f64,
    pub train_secs: f64,
}

impl Trainer {
    pub fn new(
        runtime: &Runtime,
        method: Method,
        initial: Arc<ParamSnapshot>,
        store: Arc<WeightStore>,
    ) -> Result<Trainer> {
        let train_exec = runtime.exec(method.executable())?.clone();
        let prox_exec = if method == Method::Recompute {
            Some(runtime.exec("prox_forward")?.clone())
        } else {
            None
        };
        let pretrain_exec =
            if runtime.has_exec("pretrain") { Some(runtime.exec("pretrain")?.clone()) } else { None };
        let n_params = runtime.manifest.n_params();
        if initial.params.len() != n_params {
            bail!("snapshot has {} tensors, manifest {}", initial.params.len(), n_params);
        }
        Ok(Trainer {
            method,
            train_exec,
            prox_exec,
            pretrain_exec,
            store,
            snapshot: initial,
            adam_m: runtime.zero_adam_state(),
            adam_v: runtime.zero_adam_state(),
            opt_step: 0,
            last_theta_logp: None,
            n_params,
            n_minibatch: runtime.manifest.preset.n_minibatch,
            geo_b: runtime.manifest.preset.train_batch,
            geo_s: runtime.manifest.preset.seq_len,
        })
    }

    pub fn version(&self) -> u64 {
        self.snapshot.version
    }

    pub fn snapshot(&self) -> Arc<ParamSnapshot> {
        self.snapshot.clone()
    }

    /// One RL training step (= n_minibatch gradient updates inside the
    /// executable), with the method's prox phase timed separately.
    pub fn step(&mut self, batch: &TrainBatch) -> Result<(TrainMetrics, StepTiming)> {
        let (b, s) = (self.geo_b, self.geo_s);
        let t = s - 1;
        let tokens = HostTensor::i32(vec![b, s], batch.tokens.clone());
        let mask = HostTensor::f32(vec![b, t], batch.mask.clone());
        let behav = HostTensor::f32(vec![b, t], batch.behav_logp.clone());
        let adv = HostTensor::f32(vec![b, t], batch.adv.clone());
        let alpha = HostTensor::f32(vec![b], batch.alpha.clone());

        // --- proximal-policy phase (the paper's Fig. 1 measurement) ------
        let prox_sw = Stopwatch::start();
        let prox = match self.method {
            Method::Recompute => {
                // Extra forward pass over the training batch; frozen for
                // the rest of the step.
                let exec = self.prox_exec.as_ref().expect("recompute needs prox_forward");
                let mut refs = self.snapshot.tensor_refs();
                refs.push(&tokens);
                let outs = exec.run_refs(&refs)?;
                outs.into_iter().next().unwrap()
            }
            Method::Loglinear => {
                // Eq. 3 as a standalone elementwise op (what replaces the
                // forward pass). θ log-probs come from the previous step's
                // train output; on the very first step (no θ yet) the
                // anchor degenerates to the behaviour policy, exactly the
                // d = 0 on-policy case. The train executable re-fuses the
                // interpolation with its own fresh θ, so this is
                // measurement, not double work.
                let theta: &[f32] = match &self.last_theta_logp {
                    Some(v) => v,
                    None => &batch.behav_logp,
                };
                let interp = interp_prox_host(theta, &batch.behav_logp, &batch.alpha, t);
                HostTensor::f32(vec![b, t], interp)
            }
            Method::Sync => {
                // Coupled loss: no proximal policy. Zero placeholder (the
                // executable ignores it).
                HostTensor::f32(vec![b, t], vec![0.0; b * t])
            }
        };
        let prox_secs = prox_sw.secs();

        // --- train executable --------------------------------------------
        let step_lit = HostTensor::scalar_i32(self.opt_step);
        let train_sw = Stopwatch::start();
        let mut refs = self.snapshot.tensor_refs();
        refs.extend(self.adam_m.iter());
        refs.extend(self.adam_v.iter());
        refs.push(&step_lit);
        refs.push(&tokens);
        refs.push(&mask);
        refs.push(&behav);
        refs.push(&adv);
        refs.push(&alpha);
        refs.push(&prox);
        let mut outs = self.train_exec.run_refs(&refs)?;
        let train_secs = train_sw.secs();

        // Unpack: params, m, v, step, metrics[, theta_logp].
        let np = self.n_params;
        let theta_out = if outs.len() > 3 * np + 2 { outs.pop() } else { None };
        let metrics_t = outs.pop().expect("metrics output");
        let _step_out = outs.pop().expect("step output");
        let new_v: Vec<HostTensor> = outs.split_off(2 * np);
        let new_m: Vec<HostTensor> = outs.split_off(np);
        let new_params = outs;

        if let Some(theta) = theta_out {
            self.last_theta_logp = Some(theta.as_f32()?.to_vec());
        }

        // The executable performed n_minibatch Adam updates; keep the host
        // step counter (bias correction) in lockstep.
        self.opt_step += self.n_minibatch as i32;
        self.adam_m = new_m;
        self.adam_v = new_v;
        let new_version = self.snapshot.version + 1;
        self.snapshot = ParamSnapshot::new(new_version, new_params);
        self.store.publish(self.snapshot.clone());

        let metrics = TrainMetrics::from_vector(metrics_t.as_f32()?);
        Ok((metrics, StepTiming { prox_secs, train_secs }))
    }

    /// One supervised warm-start step (next-token CE on correct solutions).
    pub fn pretrain_step(&mut self, tokens: &[i32], mask: &[f32]) -> Result<TrainMetrics> {
        let exec = match &self.pretrain_exec {
            Some(e) => e.clone(),
            None => bail!("pretrain executable not loaded"),
        };
        let (b, s) = (self.geo_b, self.geo_s);
        let tokens = HostTensor::i32(vec![b, s], tokens.to_vec());
        let mask = HostTensor::f32(vec![b, s - 1], mask.to_vec());
        let step_lit = HostTensor::scalar_i32(self.opt_step);
        let mut refs = self.snapshot.tensor_refs();
        refs.extend(self.adam_m.iter());
        refs.extend(self.adam_v.iter());
        refs.push(&step_lit);
        refs.push(&tokens);
        refs.push(&mask);
        let mut outs = exec.run_refs(&refs)?;

        let np = self.n_params;
        let metrics_t = outs.pop().expect("metrics output");
        let _step_out = outs.pop();
        let new_v: Vec<HostTensor> = outs.split_off(2 * np);
        let new_m: Vec<HostTensor> = outs.split_off(np);
        self.adam_m = new_m;
        self.adam_v = new_v;
        self.opt_step += 1;
        // Warm start does not bump the RL version: v(pi) counts RL updates.
        self.snapshot = ParamSnapshot::new(self.snapshot.version, outs);
        self.store.publish(self.snapshot.clone());
        Ok(TrainMetrics::from_vector(metrics_t.as_f32()?))
    }
}

/// Eq. 3 on the host: `log π_prox = α·log π_behav + (1-α)·log π_θ`, with α
/// broadcast per sequence row. This is the op A-3PO substitutes for
/// recompute's full forward pass; the native train executables apply the
/// same formula (with their own fresh θ) inside the fused loss.
pub fn interp_prox_host(
    theta_logp: &[f32],
    behav_logp: &[f32],
    alpha: &[f32],
    t: usize,
) -> Vec<f32> {
    assert_eq!(theta_logp.len(), behav_logp.len(), "theta/behav length mismatch");
    assert_eq!(alpha.len() * t, behav_logp.len(), "alpha rows don't cover the batch");
    let mut out = Vec::with_capacity(behav_logp.len());
    for (row, &a) in alpha.iter().enumerate() {
        let base = row * t;
        for i in base..base + t {
            out.push(a * behav_logp[i] + (1.0 - a) * theta_logp[i]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_is_a_genuine_interpolation() {
        let theta = vec![-2.0f32, -4.0, -6.0, -8.0];
        let behav = vec![-1.0f32, -2.0, -3.0, -4.0];
        // Row 0: alpha = 0.5 -> midpoint; row 1: alpha = 0.25.
        let out = interp_prox_host(&theta, &behav, &[0.5, 0.25], 2);
        assert_eq!(out, vec![-1.5, -3.0, -5.25, -7.0]);
    }

    #[test]
    fn interp_alpha_extremes_select_an_operand() {
        let theta = vec![-2.0f32, -4.0, -6.0, -8.0];
        let behav = vec![-1.0f32, -2.0, -3.0, -4.0];
        // alpha = 0: anchor at theta (on-policy). alpha = 1: anchor at the
        // behaviour policy (fully stale).
        let out = interp_prox_host(&theta, &behav, &[0.0, 1.0], 2);
        assert_eq!(&out[..2], &theta[..2]);
        assert_eq!(&out[2..], &behav[2..]);
    }

    #[test]
    #[should_panic(expected = "alpha rows")]
    fn interp_rejects_mismatched_rows() {
        interp_prox_host(&[-1.0; 4], &[-1.0; 4], &[0.5; 3], 2);
    }
}
