//! Held-out evaluation: greedy decoding on frozen prompt sets, strict
//! exact-match scoring (Fig. 3, Table 1's "final eval reward", Table 2).

use std::sync::Arc;

use anyhow::Result;

use crate::env::Problem;
use crate::rollout::generate_for_problems;
use crate::runtime::{Decoder, ParamSnapshot, PresetConfig};
use crate::sampler::SamplerConfig;
use crate::util::rng::Pcg64;
use crate::util::stats::pass_at_1;

/// Evaluate `problems` with greedy decoding; returns mean exact-match
/// reward. Problem lists that don't divide the rollout batch are padded
/// with repeats (padding rows are not scored).
pub fn evaluate_exact(
    decoder: &Decoder,
    snapshot: &Arc<ParamSnapshot>,
    problems: &[Problem],
    geo: &PresetConfig,
) -> Result<f64> {
    let (correct, total) = evaluate_counts(decoder, snapshot, problems, geo, true)?;
    Ok(if total == 0 { 0.0 } else { correct as f64 / total as f64 })
}

/// pass@1 with a binomial standard error — Table 2's reporting format.
/// `greedy=false` samples at the training temperature (closer to the
/// paper's pass@1-with-sampling protocol).
pub fn evaluate_pass_at_1(
    decoder: &Decoder,
    snapshot: &Arc<ParamSnapshot>,
    problems: &[Problem],
    geo: &PresetConfig,
    greedy: bool,
) -> Result<(f64, f64)> {
    let (correct, total) = evaluate_counts(decoder, snapshot, problems, geo, greedy)?;
    Ok(pass_at_1(correct, total))
}

fn evaluate_counts(
    decoder: &Decoder,
    snapshot: &Arc<ParamSnapshot>,
    problems: &[Problem],
    geo: &PresetConfig,
    greedy: bool,
) -> Result<(usize, usize)> {
    if problems.is_empty() {
        return Ok((0, 0));
    }
    let br = geo.rollout_batch;
    let cfg = if greedy {
        SamplerConfig::greedy()
    } else {
        SamplerConfig { temperature: geo.temperature, ..Default::default() }
    };
    // Eval sampling RNG is fixed: evaluation must not perturb or depend on
    // the training RNG streams.
    let mut rng = Pcg64::new(0xe5a1, 0xe5a1);
    let mut correct = 0usize;
    for chunk in problems.chunks(br) {
        let mut padded: Vec<Problem> = chunk.to_vec();
        while padded.len() < br {
            padded.push(chunk[0].clone());
        }
        let eps = generate_for_problems(decoder, snapshot, &padded, geo, &cfg, &mut rng)?;
        correct += eps
            .iter()
            .take(chunk.len())
            .filter(|e| e.reward_exact >= 1.0)
            .count();
    }
    Ok((correct, problems.len()))
}
