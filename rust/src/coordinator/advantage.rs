//! GRPO advantage estimation: group reward normalisation.
//!
//! The paper estimates advantages "using group reward normalization"
//! (Shao et al. 2024): for the G responses sampled from one prompt,
//! `A_i = (r_i - mean(r)) / (std(r) + eps)`, broadcast over every response
//! token. Zero-variance groups (all responses equally rewarded) produce
//! zero advantage — those groups contribute no policy gradient, exactly as
//! in GRPO.

const EPS: f64 = 1e-4;

/// Normalise one group's rewards into per-sequence advantages.
pub fn grpo_group_advantages(rewards: &[f64]) -> Vec<f64> {
    let n = rewards.len();
    assert!(n > 0);
    if n == 1 {
        return vec![0.0];
    }
    let mean = rewards.iter().sum::<f64>() / n as f64;
    let var = rewards.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / n as f64;
    let std = var.sqrt();
    rewards.iter().map(|r| (r - mean) / (std + EPS)).collect()
}

/// Expand per-sequence advantages over the masked token positions:
/// `adv_tokens[t] = adv_seq * mask[t]`.
pub fn broadcast_over_mask(adv: f64, mask: &[f32]) -> Vec<f32> {
    mask.iter().map(|&m| (adv as f32) * m).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalised_group_has_zero_mean_unit_scale() {
        let adv = grpo_group_advantages(&[1.0, 0.0, 0.0, 1.0]);
        let mean: f64 = adv.iter().sum::<f64>() / 4.0;
        assert!(mean.abs() < 1e-9);
        // std(r) = 0.5 -> adv = ±0.5/(0.5+eps) ≈ ±1
        assert!((adv[0] - 1.0).abs() < 1e-3);
        assert!((adv[1] + 1.0).abs() < 1e-3);
    }

    #[test]
    fn zero_variance_group_is_all_zero() {
        for r in [0.0, 1.0] {
            let adv = grpo_group_advantages(&[r; 4]);
            assert!(adv.iter().all(|a| a.abs() < 1e-9), "{adv:?}");
        }
    }

    #[test]
    fn singleton_group_is_zero() {
        assert_eq!(grpo_group_advantages(&[0.7]), vec![0.0]);
    }

    #[test]
    fn ordering_preserved() {
        let adv = grpo_group_advantages(&[0.2, 0.9, 0.5, 0.0]);
        assert!(adv[1] > adv[2] && adv[2] > adv[0] && adv[0] > adv[3]);
    }

    #[test]
    fn broadcast_respects_mask() {
        let out = broadcast_over_mask(2.0, &[0.0, 1.0, 1.0, 0.0]);
        assert_eq!(out, vec![0.0, 2.0, 2.0, 0.0]);
    }
}
