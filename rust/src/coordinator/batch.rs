//! Training-batch assembly: GRPO groups -> flat tensors for the train
//! executables, including the staleness-aware α of paper Eq. 4.

use crate::buffer::Episode;
use crate::config::AlphaSchedule;
use crate::runtime::PresetConfig;

use super::advantage::{broadcast_over_mask, grpo_group_advantages};

/// Flat host-side tensors matching the train executables' batch inputs.
#[derive(Debug, Clone)]
pub struct TrainBatch {
    pub tokens: Vec<i32>,     // [B * S]
    pub mask: Vec<f32>,       // [B * T]
    pub behav_logp: Vec<f32>, // [B * T]
    pub adv: Vec<f32>,        // [B * T]
    pub alpha: Vec<f32>,      // [B]
    pub staleness: Vec<u64>,  // [B] (diagnostics)
    pub mean_staleness: f64,
    pub mean_alpha: f64,
    pub mean_reward: f64,
    pub mean_reward_exact: f64,
}

/// Assemble a train batch from complete GRPO groups.
///
/// * advantages: group reward normalisation over each group's shaped
///   rewards, broadcast over masked token positions;
/// * staleness: `d = v_now - v(episode) + inject` (inject > 0 only in
///   controlled-staleness experiments);
/// * alpha: schedule(d) per sequence (Eq. 4 when schedule = InverseD).
pub fn assemble(
    groups: &[Vec<Episode>],
    geo: &PresetConfig,
    v_now: u64,
    schedule: AlphaSchedule,
    inject_staleness: u64,
) -> TrainBatch {
    let b = geo.train_batch;
    let (s, t) = (geo.seq_len, geo.seq_len - 1);
    let total: usize = groups.iter().map(|g| g.len()).sum();
    assert_eq!(total, b, "assemble needs exactly train_batch episodes");

    let mut out = TrainBatch {
        tokens: Vec::with_capacity(b * s),
        mask: Vec::with_capacity(b * t),
        behav_logp: Vec::with_capacity(b * t),
        adv: Vec::with_capacity(b * t),
        alpha: Vec::with_capacity(b),
        staleness: Vec::with_capacity(b),
        mean_staleness: 0.0,
        mean_alpha: 0.0,
        mean_reward: 0.0,
        mean_reward_exact: 0.0,
    };

    for group in groups {
        let rewards: Vec<f64> = group.iter().map(|e| e.reward).collect();
        let advs = grpo_group_advantages(&rewards);
        for (e, adv) in group.iter().zip(advs) {
            assert_eq!(e.tokens.len(), s, "episode seq_len mismatch");
            assert_eq!(e.mask.len(), t);
            let d = e.staleness(v_now) + inject_staleness;
            let a = schedule.alpha(d);
            out.tokens.extend_from_slice(&e.tokens);
            out.mask.extend_from_slice(&e.mask);
            out.behav_logp.extend_from_slice(&e.behav_logp);
            out.adv.extend(broadcast_over_mask(adv, &e.mask));
            out.alpha.push(a);
            out.staleness.push(d);
            out.mean_staleness += d as f64 / b as f64;
            out.mean_alpha += a as f64 / b as f64;
            out.mean_reward += e.reward / b as f64;
            out.mean_reward_exact += e.reward_exact / b as f64;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::Problem;

    fn geo() -> PresetConfig {
        PresetConfig {
            name: "test".into(),
            vocab: 64,
            seq_len: 6,
            prompt_len: 3,
            gen_len: 3,
            group_size: 2,
            rollout_batch: 4,
            train_batch: 4,
            n_minibatch: 2,
            param_count: 0,
            lr: 1e-3,
            temperature: 1.0,
        }
    }

    fn ep(version: u64, reward: f64) -> Episode {
        Episode {
            tokens: vec![1; 6],
            behav_logp: vec![-0.5; 5],
            mask: vec![0.0, 0.0, 1.0, 1.0, 0.0],
            reward,
            reward_exact: reward.floor(),
            version,
            group: 0,
            text: String::new(),
            problem: Problem { prompt: "1+1=".into(), answer: "2".into() },
        }
    }

    #[test]
    fn shapes_and_means() {
        let groups = vec![vec![ep(2, 1.0), ep(2, 0.0)], vec![ep(4, 1.0), ep(4, 1.0)]];
        let b = assemble(&groups, &geo(), 4, AlphaSchedule::InverseD, 0);
        assert_eq!(b.tokens.len(), 4 * 6);
        assert_eq!(b.mask.len(), 4 * 5);
        assert_eq!(b.alpha.len(), 4);
        // staleness: 2,2,0,0 -> alpha 0.5,0.5,0,0
        assert_eq!(b.staleness, vec![2, 2, 0, 0]);
        assert_eq!(b.alpha, vec![0.5, 0.5, 0.0, 0.0]);
        assert!((b.mean_staleness - 1.0).abs() < 1e-9);
        assert!((b.mean_alpha - 0.25).abs() < 1e-9);
        assert!((b.mean_reward - 0.75).abs() < 1e-9);
    }

    #[test]
    fn advantages_masked_and_group_normalised() {
        let groups = vec![vec![ep(0, 1.0), ep(0, 0.0)], vec![ep(0, 0.5), ep(0, 0.5)]];
        let b = assemble(&groups, &geo(), 0, AlphaSchedule::InverseD, 0);
        let t = 5;
        // First group: adv ±1 on masked positions (2,3), zero elsewhere.
        assert!(b.adv[0 * t] == 0.0 && b.adv[0 * t + 2] > 0.99);
        assert!(b.adv[1 * t + 2] < -0.99);
        // Zero-variance second group: all-zero advantages.
        assert!(b.adv[2 * t..4 * t].iter().all(|&a| a == 0.0));
    }

    #[test]
    fn inject_staleness_shifts_d() {
        let groups = vec![vec![ep(5, 1.0), ep(5, 0.0)], vec![ep(5, 1.0), ep(5, 0.0)]];
        let b = assemble(&groups, &geo(), 5, AlphaSchedule::InverseD, 3);
        assert!(b.staleness.iter().all(|&d| d == 3));
        assert!(b.alpha.iter().all(|&a| (a - 1.0 / 3.0).abs() < 1e-6));
    }

    #[test]
    #[should_panic(expected = "exactly train_batch")]
    fn wrong_count_panics() {
        let groups = vec![vec![ep(0, 1.0), ep(0, 0.0)]];
        assemble(&groups, &geo(), 0, AlphaSchedule::InverseD, 0);
    }
}
