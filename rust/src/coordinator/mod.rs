//! The coordinator: the paper's training-system loop.
//!
//! * **sync**: rollout and training alternate with a barrier (standard
//!   GRPO); data is always on-policy (d = 0).
//! * **recompute / loglinear**: rollout workers and the trainer run
//!   concurrently, decoupled by the staleness-tagged `EpisodeBuffer`;
//!   the trainer consumes the oldest admissible groups and publishes a new
//!   weight version after every step — behaviour-policy staleness arises
//!   naturally from this asynchrony (plus optional injection for controlled
//!   experiments).

pub mod advantage;
pub mod batch;
pub mod eval;
pub mod trainer;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::buffer::EpisodeBuffer;
use crate::config::{Method, RunOptions};
use crate::env::{self, tokenizer};
use crate::metrics::{EvalRecord, MetricsLogger, StepRecord};
use crate::rollout::{generate_batch, GroupIds, RolloutPool};
use crate::runtime::{checkpoint, ParamSnapshot, Runtime, WeightStore};
use crate::sampler::SamplerConfig;
use crate::trace;
use crate::trace::report::{StalenessHistogram, TelemetryReport};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::util::timer::{PhaseTimer, Stopwatch};

pub use trainer::Trainer;

/// Everything a finished run hands back to examples/benches.
pub struct RunOutput {
    pub logger: MetricsLogger,
    pub final_snapshot: Arc<ParamSnapshot>,
    pub final_eval: f64,
    pub total_secs: f64,
    pub phases: PhaseTimer,
    pub dropped_stale_groups: u64,
    /// Pipeline rollup: starvation, worker utilisation, buffer occupancy,
    /// staleness distribution. Populated whether or not tracing was on.
    pub telemetry: TelemetryReport,
    pub runtime: Runtime,
}

impl RunOutput {
    pub fn summary_json(&self, opts: &RunOptions) -> Json {
        Json::obj(vec![
            ("preset", Json::Str(opts.preset.clone())),
            ("method", Json::Str(opts.method.label().into())),
            ("steps", Json::Num(self.logger.steps.len() as f64)),
            ("final_eval_reward", Json::Num(self.final_eval)),
            ("total_seconds", Json::Num(self.total_secs)),
            (
                "prox_mean_ms",
                Json::Num(
                    1e3 * self.phases.total("prox") / self.phases.count("prox").max(1) as f64,
                ),
            ),
            ("dropped_stale_groups", Json::Num(self.dropped_stale_groups as f64)),
            ("trainer_wait_seconds", Json::Num(self.telemetry.trainer_wait_secs)),
            ("trainer_starvation_frac", Json::Num(self.telemetry.trainer_starvation_frac())),
            (
                "buffer_high_water_episodes",
                Json::Num(self.telemetry.buffer.high_water_episodes as f64),
            ),
            ("staleness_p50", Json::Num(self.telemetry.staleness.percentile(50.0))),
            ("staleness_p95", Json::Num(self.telemetry.staleness.percentile(95.0))),
            ("staleness_max", Json::Num(self.telemetry.staleness.max() as f64)),
        ])
    }
}

/// Exports the Chrome trace when dropped, so the trace survives error paths
/// too. `trace::stop()` drains the main thread plus everything the joined
/// worker threads flushed on exit.
struct TraceExport {
    path: String,
}

impl Drop for TraceExport {
    fn drop(&mut self) {
        let data = trace::stop();
        let n = data.events.len();
        match data.write_chrome(std::path::Path::new(&self.path)) {
            Ok(()) => {
                if std::env::var_os("A3PO_QUIET").is_none() {
                    eprintln!("[trace] wrote {n} events to {}", self.path);
                }
            }
            Err(e) => eprintln!("[trace] export failed: {e}"),
        }
    }
}

/// Executables a run needs (loading fewer saves compile time).
fn needed_execs(opts: &RunOptions) -> Vec<&'static str> {
    let mut v = vec!["init", "decode"];
    v.push(opts.method.executable());
    if opts.method == Method::Recompute {
        v.push("prox_forward");
    }
    if opts.pretrain_steps > 0 {
        v.push("pretrain");
    }
    v
}

/// Run one full training job (pretrain warm-start + RL + evals).
pub fn run(opts: &RunOptions) -> Result<RunOutput> {
    let dir = PathBuf::from(opts.artifact_dir());
    let runtime = Runtime::load(&dir, Some(&needed_execs(opts)))
        .with_context(|| format!("loading artifacts from {}", dir.display()))?;
    run_with_runtime(opts, runtime)
}

/// Same as [`run`] but with a pre-loaded runtime (benches reuse one runtime
/// across methods to avoid recompiling shared executables).
pub fn run_with_runtime(opts: &RunOptions, runtime: Runtime) -> Result<RunOutput> {
    // Tracing: `--trace <path>` / `RunOptions.trace_path` wins, `A3PO_TRACE`
    // env var is the fallback. The guard exports the file when this function
    // returns (the rollout pool is joined before then on every path).
    let trace_dest = opts
        .trace_path
        .clone()
        .or_else(|| std::env::var("A3PO_TRACE").ok())
        .filter(|p| !p.is_empty());
    let _trace_export = trace_dest.map(|path| {
        trace::start();
        TraceExport { path }
    });

    let geo = runtime.manifest.preset.clone();
    let env: Arc<dyn env::TaskEnv> =
        env::env_for_preset(&opts.preset, geo.prompt_len, geo.gen_len).into();
    let decoder = runtime.decoder()?;

    let mut rng = Pcg64::from_seed(opts.seed);
    let snapshot = match &opts.init_ckpt {
        Some(base) => {
            let loaded = checkpoint::load(&PathBuf::from(base), &runtime.manifest)?;
            eprintln!("[run] warm-starting from {base} (version reset to 0)");
            // RL versions count from 0 in every run regardless of source.
            ParamSnapshot::new(0, loaded.params.clone())
        }
        None => runtime.init_params(opts.seed as i32)?,
    };
    let store = WeightStore::new(snapshot.clone());
    let mut trainer = Trainer::new(&runtime, opts.method, snapshot, store.clone())?;
    if std::env::var_os("A3PO_QUIET").is_none() {
        eprintln!("[run] train path: {}", trainer.path_label());
    }

    let metrics_path =
        PathBuf::from(&opts.out_dir).join(format!("{}_{}.jsonl", opts.preset, opts.method.label()));
    let mut logger = MetricsLogger::to_file(&metrics_path, true)?;
    let mut phases = PhaseTimer::new();

    let heldout = env::heldout_problems(env.as_ref(), opts.seed, opts.eval_prompts);
    let sampler_cfg = SamplerConfig { temperature: geo.temperature, ..Default::default() };

    // ---- supervised warm start (pretrained-model surrogate) -------------
    if opts.pretrain_steps > 0 {
        let sw = Stopwatch::start();
        let mut pre_rng = rng.split(0x9e);
        for i in 0..opts.pretrain_steps {
            let (tokens, mask) = supervised_batch(env.as_ref(), &geo, &mut pre_rng);
            let m = trainer.pretrain_step(&tokens, &mask)?;
            if i % 20 == 0 || i + 1 == opts.pretrain_steps {
                eprintln!("[pretrain {:>4}] ce-loss={:.4}", i, m.loss);
            }
        }
        phases.add("pretrain", sw.secs());
    }

    // ---- RL ---------------------------------------------------------------
    let run_sw = Stopwatch::start();
    let groups_per_step = geo.train_batch / geo.group_size;
    let group_ids = Arc::new(GroupIds::default());

    let buffer = Arc::new(EpisodeBuffer::new(opts.staleness));
    let pool = if opts.method.is_async() {
        Some(RolloutPool::spawn(
            opts.workers,
            decoder.clone(),
            store.clone(),
            buffer.clone(),
            env.clone(),
            geo.clone(),
            sampler_cfg,
            group_ids.clone(),
            opts.seed,
        ))
    } else {
        None
    };

    let mut result: Result<()> = Ok(());
    let mut staleness_hist = StalenessHistogram::default();
    for step in 0..opts.steps {
        let _step_span = trace::span_arg("step", "trainer", "step", step as f64);
        // -- acquire a batch of groups --------------------------------
        // Async: the stopwatch measures the trainer blocked in `pop_groups`
        // (starvation). Sync: it measures inline generation.
        let acquire_sw = Stopwatch::start();
        let groups = if opts.method.is_async() {
            let _sp = trace::span("pop_groups", "buffer");
            match buffer.pop_groups(groups_per_step, trainer.version()) {
                Some(g) => g,
                None => break, // shutdown (can't happen unless errored)
            }
        } else {
            // Synchronous: generate exactly what this step consumes.
            let _sp = trace::span("generate", "rollout");
            let mut got = Vec::with_capacity(groups_per_step);
            while got.len() < groups_per_step {
                let gs = generate_batch(
                    &decoder,
                    &trainer.snapshot(),
                    env.as_ref(),
                    &geo,
                    &sampler_cfg,
                    &mut rng,
                    &group_ids,
                )?;
                got.extend(gs);
            }
            got.truncate(groups_per_step);
            got
        };
        let (rollout_secs, wait_secs) = if opts.method.is_async() {
            let w = acquire_sw.secs();
            phases.add("wait", w);
            (0.0, w)
        } else {
            let r = acquire_sw.secs();
            phases.add("rollout", r);
            (r, 0.0)
        };

        // -- assemble + train ------------------------------------------
        let assemble_span = trace::span("assemble", "trainer");
        let tb = batch::assemble(
            &groups,
            &geo,
            trainer.version(),
            opts.alpha_schedule,
            opts.inject_staleness,
        );
        drop(assemble_span);
        // The trainer consumes the batch (its buffers move into the step);
        // keep the summary stats for the log record.
        let (mean_staleness, mean_alpha) = (tb.mean_staleness, tb.mean_alpha);
        let (mean_reward, mean_reward_exact) = (tb.mean_reward, tb.mean_reward_exact);
        staleness_hist.extend(&tb.staleness);
        let row_staleness: Vec<f64> = tb.staleness.iter().map(|&d| d as f64).collect();
        let staleness_p50 = stats::percentile(&row_staleness, 50.0);
        let staleness_p95 = stats::percentile(&row_staleness, 95.0);
        let staleness_max = row_staleness.iter().copied().fold(0.0f64, f64::max);
        let step_result = trainer.step(tb);
        let (m, timing) = match step_result {
            Ok(x) => x,
            Err(e) => {
                result = Err(e);
                break;
            }
        };
        phases.add("prox", timing.prox_secs);
        phases.add("train", timing.train_secs);

        logger.log_step(StepRecord {
            step,
            wallclock: run_sw.secs(),
            version: trainer.version(),
            mean_staleness,
            mean_alpha,
            reward: mean_reward,
            reward_exact: mean_reward_exact,
            prox_secs: timing.prox_secs,
            train_secs: timing.train_secs,
            rollout_secs,
            wait_secs,
            staleness_p50,
            staleness_p95,
            staleness_max,
            train: m,
        });

        // -- periodic held-out eval -------------------------------------
        if opts.eval_every > 0 && (step + 1) % opts.eval_every == 0 {
            let sw = Stopwatch::start();
            let r = {
                let _sp = trace::span("eval", "trainer");
                eval::evaluate_exact(&decoder, &trainer.snapshot(), &heldout, &geo)?
            };
            phases.add("eval", sw.secs());
            logger.log_eval(EvalRecord {
                step,
                wallclock: run_sw.secs(),
                eval_reward: r,
                n_prompts: heldout.len(),
            });
        }
    }

    // ---- shutdown ---------------------------------------------------------
    buffer.shutdown();
    let mut workers = Vec::new();
    if let Some(pool) = pool {
        workers = pool.join()?;
    }
    result?;
    let total_secs = run_sw.secs();

    // Final held-out eval (Table 1's "Final Eval Reward").
    let final_eval = {
        let _sp = trace::span("eval", "trainer");
        eval::evaluate_exact(&decoder, &trainer.snapshot(), &heldout, &geo)?
    };
    logger.log_eval(EvalRecord {
        step: opts.steps,
        wallclock: total_secs,
        eval_reward: final_eval,
        n_prompts: heldout.len(),
    });

    if let Some(err) = logger.io_error() {
        eprintln!(
            "[metrics] WARNING: JSONL stream lost writes ({err}); in-memory records are intact"
        );
    }

    let generation_secs = if opts.method.is_async() {
        workers.iter().map(|w| w.generate_secs).sum()
    } else {
        phases.total("rollout")
    };
    let telemetry = TelemetryReport {
        total_secs,
        trainer_wait_secs: phases.total("wait"),
        trainer_busy_secs: phases.total("prox") + phases.total("train"),
        generation_secs,
        workers,
        buffer: buffer.telemetry(),
        staleness: staleness_hist,
    };

    Ok(RunOutput {
        logger,
        final_snapshot: trainer.snapshot(),
        final_eval,
        total_secs,
        phases,
        dropped_stale_groups: telemetry.buffer.dropped_stale_groups,
        telemetry,
        runtime,
    })
}

/// Save a run's final parameters as `<out>/<preset>_<method>` checkpoint.
pub fn save_checkpoint(opts: &RunOptions, out: &RunOutput) -> Result<PathBuf> {
    let base =
        PathBuf::from(&opts.out_dir).join(format!("{}_{}", opts.preset, opts.method.label()));
    checkpoint::save(&base, &out.runtime.manifest, &out.final_snapshot)?;
    Ok(base)
}

/// Build a supervised warm-start batch (correct solutions as targets).
fn supervised_batch(
    env: &dyn env::TaskEnv,
    geo: &crate::runtime::PresetConfig,
    rng: &mut Pcg64,
) -> (Vec<i32>, Vec<f32>) {
    let (b, s) = (geo.train_batch, geo.seq_len);
    let mut tokens = Vec::with_capacity(b * s);
    let mut mask = Vec::with_capacity(b * (s - 1));
    for _ in 0..b {
        let p = env.sample(rng);
        let (t, m) =
            tokenizer::encode_supervised(&p.prompt, &p.answer, geo.prompt_len, s);
        tokens.extend(t);
        mask.extend(m);
    }
    (tokens, mask)
}
