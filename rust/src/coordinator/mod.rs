//! The coordinator: the paper's training-system loop.
//!
//! * **sync**: rollout and training alternate with a barrier (standard
//!   GRPO); data is always on-policy (d = 0).
//! * **recompute / loglinear**: rollout workers and the trainer run
//!   concurrently, decoupled by the staleness-tagged `EpisodeBuffer`;
//!   the trainer consumes the oldest admissible groups and publishes a new
//!   weight version after every step — behaviour-policy staleness arises
//!   naturally from this asynchrony (plus optional injection for controlled
//!   experiments).

pub mod advantage;
pub mod batch;
pub mod eval;
pub mod trainer;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::buffer::EpisodeBuffer;
use crate::config::{Method, RunOptions};
use crate::env::{self, tokenizer};
use crate::metrics::{EvalRecord, MetricsLogger, StepRecord};
use crate::rollout::{generate_batch, GroupIds, RolloutPool};
use crate::runtime::{checkpoint, ParamSnapshot, Runtime, WeightStore};
use crate::sampler::SamplerConfig;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::timer::{PhaseTimer, Stopwatch};

pub use trainer::Trainer;

/// Everything a finished run hands back to examples/benches.
pub struct RunOutput {
    pub logger: MetricsLogger,
    pub final_snapshot: Arc<ParamSnapshot>,
    pub final_eval: f64,
    pub total_secs: f64,
    pub phases: PhaseTimer,
    pub dropped_stale_groups: u64,
    pub runtime: Runtime,
}

impl RunOutput {
    pub fn summary_json(&self, opts: &RunOptions) -> Json {
        Json::obj(vec![
            ("preset", Json::Str(opts.preset.clone())),
            ("method", Json::Str(opts.method.label().into())),
            ("steps", Json::Num(self.logger.steps.len() as f64)),
            ("final_eval_reward", Json::Num(self.final_eval)),
            ("total_seconds", Json::Num(self.total_secs)),
            (
                "prox_mean_ms",
                Json::Num(
                    1e3 * self.phases.total("prox") / self.phases.count("prox").max(1) as f64,
                ),
            ),
            ("dropped_stale_groups", Json::Num(self.dropped_stale_groups as f64)),
        ])
    }
}

/// Executables a run needs (loading fewer saves compile time).
fn needed_execs(opts: &RunOptions) -> Vec<&'static str> {
    let mut v = vec!["init", "decode"];
    v.push(opts.method.executable());
    if opts.method == Method::Recompute {
        v.push("prox_forward");
    }
    if opts.pretrain_steps > 0 {
        v.push("pretrain");
    }
    v
}

/// Run one full training job (pretrain warm-start + RL + evals).
pub fn run(opts: &RunOptions) -> Result<RunOutput> {
    let dir = PathBuf::from(opts.artifact_dir());
    let runtime = Runtime::load(&dir, Some(&needed_execs(opts)))
        .with_context(|| format!("loading artifacts from {}", dir.display()))?;
    run_with_runtime(opts, runtime)
}

/// Same as [`run`] but with a pre-loaded runtime (benches reuse one runtime
/// across methods to avoid recompiling shared executables).
pub fn run_with_runtime(opts: &RunOptions, runtime: Runtime) -> Result<RunOutput> {
    let geo = runtime.manifest.preset.clone();
    let env: Arc<dyn env::TaskEnv> =
        env::env_for_preset(&opts.preset, geo.prompt_len, geo.gen_len).into();
    let decoder = runtime.decoder()?;

    let mut rng = Pcg64::from_seed(opts.seed);
    let snapshot = match &opts.init_ckpt {
        Some(base) => {
            let loaded = checkpoint::load(&PathBuf::from(base), &runtime.manifest)?;
            eprintln!("[run] warm-starting from {base} (version reset to 0)");
            // RL versions count from 0 in every run regardless of source.
            ParamSnapshot::new(0, loaded.params.clone())
        }
        None => runtime.init_params(opts.seed as i32)?,
    };
    let store = WeightStore::new(snapshot.clone());
    let mut trainer = Trainer::new(&runtime, opts.method, snapshot, store.clone())?;
    if std::env::var_os("A3PO_QUIET").is_none() {
        eprintln!("[run] train path: {}", trainer.path_label());
    }

    let metrics_path =
        PathBuf::from(&opts.out_dir).join(format!("{}_{}.jsonl", opts.preset, opts.method.label()));
    let mut logger = MetricsLogger::to_file(&metrics_path, true)?;
    let mut phases = PhaseTimer::new();

    let heldout = env::heldout_problems(env.as_ref(), opts.seed, opts.eval_prompts);
    let sampler_cfg = SamplerConfig { temperature: geo.temperature, ..Default::default() };

    // ---- supervised warm start (pretrained-model surrogate) -------------
    if opts.pretrain_steps > 0 {
        let sw = Stopwatch::start();
        let mut pre_rng = rng.split(0x9e);
        for i in 0..opts.pretrain_steps {
            let (tokens, mask) = supervised_batch(env.as_ref(), &geo, &mut pre_rng);
            let m = trainer.pretrain_step(&tokens, &mask)?;
            if i % 20 == 0 || i + 1 == opts.pretrain_steps {
                eprintln!("[pretrain {:>4}] ce-loss={:.4}", i, m.loss);
            }
        }
        phases.add("pretrain", sw.secs());
    }

    // ---- RL ---------------------------------------------------------------
    let run_sw = Stopwatch::start();
    let groups_per_step = geo.train_batch / geo.group_size;
    let group_ids = Arc::new(GroupIds::default());

    let buffer = Arc::new(EpisodeBuffer::new(opts.staleness));
    let pool = if opts.method.is_async() {
        Some(RolloutPool::spawn(
            opts.workers,
            decoder.clone(),
            store.clone(),
            buffer.clone(),
            env.clone(),
            geo.clone(),
            sampler_cfg,
            group_ids.clone(),
            opts.seed,
        ))
    } else {
        None
    };

    let mut result: Result<()> = Ok(());
    for step in 0..opts.steps {
        // -- acquire a batch of groups --------------------------------
        let rollout_sw = Stopwatch::start();
        let groups = if opts.method.is_async() {
            match buffer.pop_groups(groups_per_step, trainer.version()) {
                Some(g) => g,
                None => break, // shutdown (can't happen unless errored)
            }
        } else {
            // Synchronous: generate exactly what this step consumes.
            let mut got = Vec::with_capacity(groups_per_step);
            while got.len() < groups_per_step {
                let gs = generate_batch(
                    &decoder,
                    &trainer.snapshot(),
                    env.as_ref(),
                    &geo,
                    &sampler_cfg,
                    &mut rng,
                    &group_ids,
                )?;
                got.extend(gs);
            }
            got.truncate(groups_per_step);
            got
        };
        let rollout_secs = rollout_sw.secs();
        phases.add("rollout", rollout_secs);

        // -- assemble + train ------------------------------------------
        let tb = batch::assemble(
            &groups,
            &geo,
            trainer.version(),
            opts.alpha_schedule,
            opts.inject_staleness,
        );
        // The trainer consumes the batch (its buffers move into the step);
        // keep the summary stats for the log record.
        let (mean_staleness, mean_alpha) = (tb.mean_staleness, tb.mean_alpha);
        let (mean_reward, mean_reward_exact) = (tb.mean_reward, tb.mean_reward_exact);
        let step_result = trainer.step(tb);
        let (m, timing) = match step_result {
            Ok(x) => x,
            Err(e) => {
                result = Err(e);
                break;
            }
        };
        phases.add("prox", timing.prox_secs);
        phases.add("train", timing.train_secs);

        logger.log_step(StepRecord {
            step,
            wallclock: run_sw.secs(),
            version: trainer.version(),
            mean_staleness,
            mean_alpha,
            reward: mean_reward,
            reward_exact: mean_reward_exact,
            prox_secs: timing.prox_secs,
            train_secs: timing.train_secs,
            rollout_secs,
            train: m,
        });

        // -- periodic held-out eval -------------------------------------
        if opts.eval_every > 0 && (step + 1) % opts.eval_every == 0 {
            let sw = Stopwatch::start();
            let r = eval::evaluate_exact(&decoder, &trainer.snapshot(), &heldout, &geo)?;
            phases.add("eval", sw.secs());
            logger.log_eval(EvalRecord {
                step,
                wallclock: run_sw.secs(),
                eval_reward: r,
                n_prompts: heldout.len(),
            });
        }
    }

    // ---- shutdown ---------------------------------------------------------
    buffer.shutdown();
    if let Some(pool) = pool {
        pool.join()?;
    }
    result?;
    let total_secs = run_sw.secs();

    // Final held-out eval (Table 1's "Final Eval Reward").
    let final_eval = eval::evaluate_exact(&decoder, &trainer.snapshot(), &heldout, &geo)?;
    logger.log_eval(EvalRecord {
        step: opts.steps,
        wallclock: total_secs,
        eval_reward: final_eval,
        n_prompts: heldout.len(),
    });

    let dropped = buffer
        .stats
        .dropped_stale_groups
        .load(std::sync::atomic::Ordering::Relaxed);

    Ok(RunOutput {
        logger,
        final_snapshot: trainer.snapshot(),
        final_eval,
        total_secs,
        phases,
        dropped_stale_groups: dropped,
        runtime,
    })
}

/// Save a run's final parameters as `<out>/<preset>_<method>` checkpoint.
pub fn save_checkpoint(opts: &RunOptions, out: &RunOutput) -> Result<PathBuf> {
    let base =
        PathBuf::from(&opts.out_dir).join(format!("{}_{}", opts.preset, opts.method.label()));
    checkpoint::save(&base, &out.runtime.manifest, &out.final_snapshot)?;
    Ok(base)
}

/// Build a supervised warm-start batch (correct solutions as targets).
fn supervised_batch(
    env: &dyn env::TaskEnv,
    geo: &crate::runtime::PresetConfig,
    rng: &mut Pcg64,
) -> (Vec<i32>, Vec<f32>) {
    let (b, s) = (geo.train_batch, geo.seq_len);
    let mut tokens = Vec::with_capacity(b * s);
    let mut mask = Vec::with_capacity(b * (s - 1));
    for _ in 0..b {
        let p = env.sample(rng);
        let (t, m) =
            tokenizer::encode_supervised(&p.prompt, &p.answer, geo.prompt_len, s);
        tokens.extend(t);
        mask.extend(m);
    }
    (tokens, mask)
}
