//! Frozen held-out benchmark suites — the AIME24 / MATH500 surrogates of
//! paper Table 2.
//!
//! Each suite is a deterministic, seed-frozen problem list that no training
//! run ever samples from (the generator streams are tagged differently from
//! both training and periodic-eval streams). `aime_like` is small and hard
//! (30 problems, matching AIME24's 30); `math_like` is larger and mixed
//! (500 problems, matching MATH500).

use super::arith::ArithEnv;
use super::chain::ChainEnv;
use super::{Problem, TaskEnv};
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct Suite {
    pub name: &'static str,
    pub problems: Vec<Problem>,
}

const AIME_STREAM: u64 = 0xa13e_2024;
const MATH_STREAM: u64 = 0x3a74_0500;

/// AIME24 surrogate: 30 hard modular-chain problems.
pub fn aime_like() -> Suite {
    let env = ChainEnv::hard();
    let mut rng = Pcg64::new(0xa3b0beac, AIME_STREAM);
    Suite { name: "AIME24-like", problems: (0..30).map(|_| env.sample(&mut rng)).collect() }
}

/// MATH500 surrogate: 500 problems mixing chain and arithmetic styles.
pub fn math_like() -> Suite {
    let chain = ChainEnv::standard();
    let arith = ArithEnv::standard();
    let mut rng = Pcg64::new(0xa3b0beac, MATH_STREAM);
    let problems = (0..500)
        .map(|i| {
            if i % 2 == 0 {
                chain.sample(&mut rng)
            } else {
                arith.sample(&mut rng)
            }
        })
        .collect();
    Suite { name: "MATH500-like", problems }
}

/// Both Table-2 suites.
pub fn table2_suites() -> Vec<Suite> {
    vec![aime_like(), math_like()]
}

/// A suite restricted to problems that fit a preset's geometry (arith
/// prompts fit everywhere; chain prompts need the setup2 window).
pub fn fitting(suite: &Suite, max_prompt_chars: usize, max_answer_chars: usize) -> Suite {
    Suite {
        name: suite.name,
        problems: suite
            .problems
            .iter()
            .filter(|p| {
                p.prompt.len() <= max_prompt_chars && p.answer.len() <= max_answer_chars
            })
            .cloned()
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::verifier::eval_expression;

    #[test]
    fn suites_are_frozen() {
        let a = aime_like();
        let b = aime_like();
        assert_eq!(a.problems, b.problems);
        assert_eq!(a.problems.len(), 30);
        assert_eq!(math_like().problems.len(), 500);
    }

    #[test]
    fn suite_answers_verify() {
        for suite in table2_suites() {
            for p in &suite.problems {
                let v = eval_expression(p.prompt.trim_end_matches('='))
                    .unwrap_or_else(|| panic!("bad {}", p.prompt));
                assert_eq!(v.to_string(), p.answer);
            }
        }
    }

    #[test]
    fn suites_disjoint_from_heldout_eval() {
        // Different stream tags must produce different problem lists.
        let env = ChainEnv::standard();
        let eval = crate::env::heldout_problems(&env, 0xa3b0beac, 30);
        let aime = aime_like();
        assert_ne!(eval, aime.problems);
    }

    #[test]
    fn fitting_filters() {
        let s = math_like();
        let f = fitting(&s, 10, 5);
        assert!(f.problems.len() < s.problems.len());
        assert!(!f.problems.is_empty());
        assert!(f.problems.iter().all(|p| p.prompt.len() <= 10));
    }
}
