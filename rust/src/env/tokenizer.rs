//! Character-level tokenizer for the synthetic math tasks.
//!
//! The vocabulary layout is a fixed contract with `python/compile/config.py`
//! (VOCAB_SIZE / PAD / BOS / EOS / SEP): the embedding table is sized and
//! indexed identically on both sides of the AOT boundary.

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3; // '='
pub const DIGIT0: i32 = 4; // '0'..'9' -> 4..13

pub const VOCAB_SIZE: usize = 64;

/// Map a character to its token id, if representable.
pub fn encode_char(c: char) -> Option<i32> {
    Some(match c {
        '=' => SEP,
        '0'..='9' => DIGIT0 + (c as i32 - '0' as i32),
        '+' => 14,
        '-' => 15,
        '*' => 16,
        '%' => 17,
        '(' => 18,
        ')' => 19,
        ' ' => 20,
        _ => return None,
    })
}

pub fn decode_token(t: i32) -> Option<char> {
    Some(match t {
        SEP => '=',
        t if (DIGIT0..DIGIT0 + 10).contains(&t) => {
            char::from(b'0' + (t - DIGIT0) as u8)
        }
        14 => '+',
        15 => '-',
        16 => '*',
        17 => '%',
        18 => '(',
        19 => ')',
        20 => ' ',
        _ => return None,
    })
}

/// Encode a string; panics on unrepresentable characters (task generators
/// only emit the symbols above — anything else is a programming error).
pub fn encode(s: &str) -> Vec<i32> {
    s.chars()
        .map(|c| encode_char(c).unwrap_or_else(|| panic!("untokenizable char {c:?}")))
        .collect()
}

/// Decode a token slice, stopping at EOS/PAD; specials are skipped.
pub fn decode(tokens: &[i32]) -> String {
    let mut out = String::new();
    for &t in tokens {
        if t == EOS || t == PAD {
            break;
        }
        if let Some(c) = decode_token(t) {
            out.push(c);
        }
    }
    out
}

/// Left-pad a prompt into a fixed window: `[PAD.., BOS, prompt..]`.
/// Generation then starts at exactly `prompt_len` for every sequence in a
/// batch, which is what the fixed-shape decode executable requires.
pub fn encode_prompt_padded(prompt: &str, prompt_len: usize) -> Vec<i32> {
    let body = encode(prompt);
    let used = body.len() + 1; // + BOS
    assert!(
        used <= prompt_len,
        "prompt {prompt:?} ({used} tokens) exceeds prompt_len {prompt_len}"
    );
    let mut out = vec![PAD; prompt_len - used];
    out.push(BOS);
    out.extend(body);
    out
}

/// Build a full supervised sequence `[prompt window][answer, EOS, PAD..]`
/// and the loss mask over the answer region. The mask is aligned with the
/// next-token targets (length `seq_len - 1`): position t scores the token
/// at t+1, so mask[t] = 1 iff token t+1 is part of `answer + EOS`.
pub fn encode_supervised(
    prompt: &str,
    answer: &str,
    prompt_len: usize,
    seq_len: usize,
) -> (Vec<i32>, Vec<f32>) {
    let mut tokens = encode_prompt_padded(prompt, prompt_len);
    let ans = encode(answer);
    assert!(
        prompt_len + ans.len() + 1 <= seq_len,
        "answer {answer:?} does not fit in seq_len {seq_len}"
    );
    tokens.extend(&ans);
    tokens.push(EOS);
    tokens.resize(seq_len, PAD);

    let mut mask = vec![0.0f32; seq_len - 1];
    for (t, m) in mask.iter_mut().enumerate() {
        let next = t + 1;
        if next >= prompt_len && next < prompt_len + ans.len() + 1 {
            *m = 1.0;
        }
    }
    (tokens, mask)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn char_roundtrip() {
        for c in "0123456789+-*%()= ".chars() {
            let t = encode_char(c).unwrap();
            assert_eq!(decode_token(t), Some(c));
            assert!((t as usize) < VOCAB_SIZE);
        }
        assert_eq!(encode_char('x'), None);
    }

    #[test]
    fn string_roundtrip() {
        let s = "12+34*5=";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn decode_stops_at_eos() {
        let mut toks = encode("42");
        toks.push(EOS);
        toks.extend(encode("99"));
        assert_eq!(decode(&toks), "42");
    }

    #[test]
    fn prompt_left_padded() {
        let p = encode_prompt_padded("1+2=", 8);
        assert_eq!(p.len(), 8);
        assert_eq!(&p[..3], &[PAD, PAD, PAD]);
        assert_eq!(p[3], BOS);
        assert_eq!(decode_token(p[7]), Some('='));
    }

    #[test]
    fn supervised_mask_covers_answer_and_eos() {
        let (toks, mask) = encode_supervised("1+2=", "3", 8, 12);
        assert_eq!(toks.len(), 12);
        assert_eq!(mask.len(), 11);
        // answer token at pos 8, EOS at pos 9 -> mask[7] and mask[8] set.
        assert_eq!(toks[8], DIGIT0 + 3);
        assert_eq!(toks[9], EOS);
        let on: Vec<usize> =
            mask.iter().enumerate().filter(|(_, &m)| m > 0.0).map(|(i, _)| i).collect();
        assert_eq!(on, vec![7, 8]);
    }

    #[test]
    #[should_panic(expected = "exceeds prompt_len")]
    fn oversized_prompt_panics() {
        encode_prompt_padded("123456789+1=", 4);
    }
}
