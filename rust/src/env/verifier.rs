//! Answer verification and reward computation.
//!
//! The training reward follows the paper's verifiable-reward recipe: exact
//! answer match. Because our surrogate models train from a brief warm start
//! rather than a full pretrained LLM, the *training* reward adds a small
//! partial credit for matching answer prefixes — this shapes early learning
//! without changing what "solved" means. All *reported* eval numbers
//! (Fig. 3, Tables 1–2) use strict exact match only.
//!
//! This module also contains a tiny expression evaluator used to
//! cross-check the generators and to support arbitrary user-supplied
//! problems in the examples.

/// Evaluate `a op b [op c ...]` with standard precedence ('*' and '%' bind
/// tighter than '+'/'-'). Supports parentheses. Returns None on malformed
/// input or division-by-zero style errors.
pub fn eval_expression(expr: &str) -> Option<i64> {
    let tokens = lex(expr)?;
    let mut pos = 0;
    let v = parse_sum(&tokens, &mut pos)?;
    if pos == tokens.len() {
        Some(v)
    } else {
        None
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Tok {
    Num(i64),
    Op(char),
    LParen,
    RParen,
}

fn lex(s: &str) -> Option<Vec<Tok>> {
    let mut out = Vec::new();
    let b = s.as_bytes();
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b' ' => i += 1,
            b'(' => {
                out.push(Tok::LParen);
                i += 1;
            }
            b')' => {
                out.push(Tok::RParen);
                i += 1;
            }
            b'+' | b'-' | b'*' | b'%' => {
                out.push(Tok::Op(b[i] as char));
                i += 1;
            }
            b'0'..=b'9' => {
                let start = i;
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
                out.push(Tok::Num(s[start..i].parse().ok()?));
            }
            _ => return None,
        }
    }
    Some(out)
}

fn parse_sum(t: &[Tok], pos: &mut usize) -> Option<i64> {
    // Unary minus on the first term.
    let mut acc = if t.get(*pos) == Some(&Tok::Op('-')) {
        *pos += 1;
        -parse_product(t, pos)?
    } else {
        parse_product(t, pos)?
    };
    while let Some(Tok::Op(op @ ('+' | '-'))) = t.get(*pos) {
        let op = *op;
        *pos += 1;
        let rhs = parse_product(t, pos)?;
        acc = if op == '+' { acc.checked_add(rhs)? } else { acc.checked_sub(rhs)? };
    }
    Some(acc)
}

fn parse_product(t: &[Tok], pos: &mut usize) -> Option<i64> {
    let mut acc = parse_atom(t, pos)?;
    while let Some(Tok::Op(op @ ('*' | '%'))) = t.get(*pos) {
        let op = *op;
        *pos += 1;
        let rhs = parse_atom(t, pos)?;
        acc = if op == '*' {
            acc.checked_mul(rhs)?
        } else {
            // Euclidean-style non-negative modulus (what the chain env uses).
            if rhs == 0 {
                return None;
            }
            acc.rem_euclid(rhs)
        };
    }
    Some(acc)
}

fn parse_atom(t: &[Tok], pos: &mut usize) -> Option<i64> {
    match t.get(*pos)? {
        Tok::Num(n) => {
            *pos += 1;
            Some(*n)
        }
        Tok::LParen => {
            *pos += 1;
            let v = parse_sum(t, pos)?;
            if t.get(*pos) == Some(&Tok::RParen) {
                *pos += 1;
                Some(v)
            } else {
                None
            }
        }
        _ => None,
    }
}

/// Strict exact-match reward (used for all reported evaluation numbers).
pub fn exact_reward(generated: &str, expected: &str) -> f64 {
    if generated == expected {
        1.0
    } else {
        0.0
    }
}

/// Shaped training reward: 1.0 for exact match, otherwise up to 0.2 of
/// partial credit for a matching prefix (per-character, position-wise).
/// Bounded strictly below the exact-match reward so the optimum is
/// unchanged.
pub fn shaped_reward(generated: &str, expected: &str) -> f64 {
    if generated == expected {
        return 1.0;
    }
    if expected.is_empty() {
        return 0.0;
    }
    let matching = generated
        .chars()
        .zip(expected.chars())
        .take_while(|(a, b)| a == b)
        .count();
    0.2 * matching as f64 / expected.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precedence_and_parens() {
        assert_eq!(eval_expression("2+3*4"), Some(14));
        assert_eq!(eval_expression("(2+3)*4"), Some(20));
        assert_eq!(eval_expression("10-2-3"), Some(5));
        assert_eq!(eval_expression("((7+5)%5*3)%7"), Some(6));
        assert_eq!(eval_expression("-3+10"), Some(7));
    }

    #[test]
    fn mod_is_non_negative() {
        assert_eq!(eval_expression("0-7%3"), Some(-1)); // -(7%3)? no: 0 - (7%3) = -1
        assert_eq!(eval_expression("(0-7)%3"), Some(2)); // rem_euclid
    }

    #[test]
    fn rejects_malformed() {
        assert_eq!(eval_expression("2+"), None);
        assert_eq!(eval_expression("(2+3"), None);
        assert_eq!(eval_expression("2++3"), None);
        assert_eq!(eval_expression("abc"), None);
        assert_eq!(eval_expression("7%0"), None);
    }

    #[test]
    fn rewards() {
        assert_eq!(exact_reward("42", "42"), 1.0);
        assert_eq!(exact_reward("4", "42"), 0.0);
        assert_eq!(shaped_reward("42", "42"), 1.0);
        assert!((shaped_reward("41", "42") - 0.1).abs() < 1e-12);
        assert_eq!(shaped_reward("9", "42"), 0.0);
        assert!(shaped_reward("4", "42") < 1.0);
    }
}
