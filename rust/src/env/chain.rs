//! DAPO-Math surrogate (paper Setup 2): longer modular-arithmetic chains.
//!
//! Harder and longer than the Setup-1 arithmetic: nested parenthesised
//! expressions with a modulus, e.g. `((417+88)%53*9)%41=`. The final `%m`
//! keeps answers small and non-negative, which keeps the task verifiable
//! with short generations while demanding genuinely multi-step computation.

use super::{Problem, TaskEnv};
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct ChainEnv {
    max_operand: i64,
    max_modulus: i64,
    /// Number of (op, operand) steps in the chain, inclusive range.
    steps: (usize, usize),
    name: &'static str,
}

impl ChainEnv {
    /// Setup-2 distribution. Small moduli keep the answer space learnable
    /// for surrogate-scale models while the chain still requires genuinely
    /// multi-step modular reasoning (the DAPO-Math difficulty knob).
    pub fn standard() -> ChainEnv {
        ChainEnv { max_operand: 100, max_modulus: 20, steps: (2, 2), name: "modchain" }
    }

    /// Harder distribution for the AIME-like held-out suite.
    pub fn hard() -> ChainEnv {
        ChainEnv { max_operand: 1000, max_modulus: 97, steps: (3, 3), name: "modchain-hard" }
    }
}

impl TaskEnv for ChainEnv {
    fn name(&self) -> &'static str {
        self.name
    }

    fn sample(&self, rng: &mut Pcg64) -> Problem {
        let n_steps = self.steps.0 + rng.below((self.steps.1 - self.steps.0 + 1) as u64) as usize;
        let m = rng.range_i64(5, self.max_modulus + 1);
        let mut expr = format!("{}", rng.range_i64(0, self.max_operand));
        let mut value: i64 = expr.parse().unwrap();
        for step in 0..n_steps {
            let op = rng.below(3) as usize;
            // After the first step values are already reduced mod m, so
            // multiplication stays bounded.
            let operand = if op == 2 {
                rng.range_i64(2, 10)
            } else {
                rng.range_i64(0, self.max_operand)
            };
            let opc = ['+', '-', '*'][op];
            expr = format!("({expr}{opc}{operand})%{m}");
            value = match op {
                0 => value + operand,
                1 => value - operand,
                _ => value * operand,
            }
            .rem_euclid(m);
            // The intermediate result is reduced each step; keep going.
            let _ = step;
        }
        Problem { prompt: format!("{expr}="), answer: value.to_string() }
    }

    fn max_prompt_chars(&self) -> usize {
        // Initial operand (<=3 chars) + per step "(...op NNN)%MM" adds at
        // most 1+1+3+2+2 = 9 chars, + trailing '='. Verified empirically in
        // `prompt_lengths_bounded`.
        3 + self.steps.1 * 9 + 1
    }

    fn max_answer_chars(&self) -> usize {
        2 // result < max_modulus <= 97
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::verifier::eval_expression;

    #[test]
    fn answers_verify_against_evaluator() {
        let env = ChainEnv::standard();
        let mut rng = Pcg64::from_seed(7);
        for _ in 0..500 {
            let p = env.sample(&mut rng);
            let expr = p.prompt.trim_end_matches('=');
            let v = eval_expression(expr).unwrap_or_else(|| panic!("bad expr {expr}"));
            assert_eq!(v.to_string(), p.answer, "expr={expr}");
        }
    }

    #[test]
    fn answers_always_reduced() {
        let env = ChainEnv::standard();
        let mut rng = Pcg64::from_seed(8);
        for _ in 0..500 {
            let p = env.sample(&mut rng);
            let v: i64 = p.answer.parse().unwrap();
            assert!((0..20).contains(&v), "answer {v} out of range");
        }
    }

    #[test]
    fn prompt_lengths_bounded() {
        for env in [ChainEnv::standard(), ChainEnv::hard()] {
            let mut rng = Pcg64::from_seed(9);
            let mut max_seen = 0;
            for _ in 0..2000 {
                let p = env.sample(&mut rng);
                max_seen = max_seen.max(p.prompt.len());
            }
            assert!(
                max_seen <= env.max_prompt_chars(),
                "{}: saw {max_seen} > bound {}",
                env.name(),
                env.max_prompt_chars()
            );
        }
    }
}
