//! GSM8K surrogate (paper Setup 1): short multi-step arithmetic problems.
//!
//! GSM8K problems need 2–8 elementary arithmetic steps; this generator
//! produces 1–2-step expressions over small operands with standard
//! precedence, e.g. `17+4*23=`. The verifiable-answer structure (one exact
//! numeric answer per prompt) is what the RL loop actually exercises.

use super::{Problem, TaskEnv};
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct ArithEnv {
    /// Operand upper bound (exclusive).
    max_operand: i64,
    /// Probability of a 2-step expression (vs a single operation).
    two_step_prob: f64,
    name: &'static str,
}

impl ArithEnv {
    /// Single-digit-friendly variant for the `tiny` preset (prompt_len 12).
    pub fn easy() -> ArithEnv {
        ArithEnv { max_operand: 10, two_step_prob: 0.0, name: "arith-easy" }
    }

    /// Setup-1 distribution: up-to-two-digit operands, ~30% two-step
    /// problems. Tuned so a warm-started surrogate model lands in the
    /// paper's initial-accuracy regime (GSM8K is "2-8 easy steps"; the
    /// learnability knob here is operand size, not step count).
    pub fn standard() -> ArithEnv {
        ArithEnv { max_operand: 50, two_step_prob: 0.3, name: "arith" }
    }

    fn op_char(op: usize) -> char {
        ['+', '-', '*'][op]
    }

    fn apply(a: i64, op: usize, b: i64) -> i64 {
        match op {
            0 => a + b,
            1 => a - b,
            _ => a * b,
        }
    }
}

impl TaskEnv for ArithEnv {
    fn name(&self) -> &'static str {
        self.name
    }

    fn sample(&self, rng: &mut Pcg64) -> Problem {
        let m = self.max_operand;
        let a = rng.range_i64(0, m);
        let b = rng.range_i64(0, m);
        // Keep products bounded: multiplication draws from a smaller range.
        let small = |rng: &mut Pcg64| rng.range_i64(0, m.min(12));
        if rng.next_f64() < self.two_step_prob {
            // a op1 b op2 c with standard precedence ('*' binds tighter).
            let op1 = rng.below(3) as usize;
            let op2 = rng.below(3) as usize;
            let (a, b, c) = match (op1, op2) {
                (2, 2) => (small(rng), small(rng) % 10, small(rng) % 10),
                (2, _) => (small(rng), small(rng), rng.range_i64(0, m)),
                (_, 2) => (a, small(rng), small(rng)),
                _ => (a, b, rng.range_i64(0, m)),
            };
            let value = match (op1, op2) {
                // '*' second binds tighter: a op1 (b*c)
                (o1, 2) => Self::apply(a, o1, b * c),
                // otherwise left-to-right: (a op1 b) op2 c
                (o1, o2) => Self::apply(Self::apply(a, o1, b), o2, c),
            };
            Problem {
                prompt: format!(
                    "{a}{}{b}{}{c}=",
                    Self::op_char(op1),
                    Self::op_char(op2)
                ),
                answer: value.to_string(),
            }
        } else {
            let op = rng.below(3) as usize;
            let (a, b) = if op == 2 { (small(rng), small(rng)) } else { (a, b) };
            Problem {
                prompt: format!("{a}{}{b}=", Self::op_char(op)),
                answer: Self::apply(a, op, b).to_string(),
            }
        }
    }

    fn max_prompt_chars(&self) -> usize {
        // "99-99*99=" style: 3 operands (<=2 digits at max_operand 100) + 2
        // ops + '=' -> 9 chars. For easy: "9+9=" -> 4 chars.
        if self.max_operand <= 10 {
            4
        } else {
            9
        }
    }

    fn max_answer_chars(&self) -> usize {
        if self.max_operand <= 10 {
            2 // up to 81 / -9
        } else {
            5 // e.g. -29*29-99 ~ -940, 99+29*29 = 940, bound generously
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::verifier::eval_expression;

    #[test]
    fn answers_verify_against_evaluator() {
        let env = ArithEnv::standard();
        let mut rng = Pcg64::from_seed(1);
        for _ in 0..500 {
            let p = env.sample(&mut rng);
            let expr = p.prompt.trim_end_matches('=');
            let v = eval_expression(expr).unwrap_or_else(|| panic!("bad expr {expr}"));
            assert_eq!(v.to_string(), p.answer, "expr={expr}");
        }
    }

    #[test]
    fn lengths_within_bounds() {
        for env in [ArithEnv::easy(), ArithEnv::standard()] {
            let mut rng = Pcg64::from_seed(2);
            for _ in 0..1000 {
                let p = env.sample(&mut rng);
                assert!(
                    p.prompt.len() <= env.max_prompt_chars(),
                    "prompt too long: {}",
                    p.prompt
                );
                assert!(
                    p.answer.len() <= env.max_answer_chars(),
                    "answer too long: {} for {}",
                    p.answer,
                    p.prompt
                );
            }
        }
    }

    #[test]
    fn easy_is_single_step() {
        let env = ArithEnv::easy();
        let mut rng = Pcg64::from_seed(3);
        for _ in 0..100 {
            let p = env.sample(&mut rng);
            let ops = p.prompt.matches(|c| "+-*".contains(c)).count();
            assert_eq!(ops, 1, "{}", p.prompt);
        }
    }
}
