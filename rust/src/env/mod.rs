//! Synthetic verifiable-math task environments.
//!
//! These stand in for the paper's datasets (GSM8K / DAPO-Math-17k) and
//! benchmarks (AIME24 / MATH500) — see DESIGN.md's substitution table. The
//! essential structure is preserved: prompts with a single verifiable
//! numeric answer, group sampling (GRPO), exact-match evaluation, and
//! held-out suites that are never trained on.

pub mod arith;
pub mod chain;
pub mod suites;
pub mod tokenizer;
pub mod verifier;

use crate::util::rng::Pcg64;

/// One problem instance: the prompt shown to the model and the verifier's
/// expected answer (both in tokenizer surface syntax).
#[derive(Debug, Clone, PartialEq)]
pub struct Problem {
    pub prompt: String,
    pub answer: String,
}

/// A task distribution. Generators must be deterministic functions of the
/// RNG so that seeded runs reproduce exactly.
pub trait TaskEnv: Send + Sync {
    fn name(&self) -> &'static str;
    /// Sample a training problem.
    fn sample(&self, rng: &mut Pcg64) -> Problem;
    /// Longest prompt string this env can emit (chars, including the
    /// trailing '=', excluding BOS).
    fn max_prompt_chars(&self) -> usize;
    /// Longest answer this env can emit (chars, excluding EOS).
    fn max_answer_chars(&self) -> usize;
}

/// Select the env that corresponds to an artifact preset, checking that its
/// prompts/answers fit the preset's compiled geometry.
pub fn env_for_preset(
    preset: &str,
    prompt_len: usize,
    gen_len: usize,
) -> Box<dyn TaskEnv> {
    let env: Box<dyn TaskEnv> = match preset {
        // setup1 surrogate: GSM8K-like short multi-step arithmetic.
        "tiny" => Box::new(arith::ArithEnv::easy()),
        "setup1" => Box::new(arith::ArithEnv::standard()),
        // setup2 surrogate: DAPO-Math-like longer modular chains.
        "setup2" | "big" => Box::new(chain::ChainEnv::standard()),
        other => panic!("no environment mapped for preset {other:?}"),
    };
    assert!(
        env.max_prompt_chars() + 1 <= prompt_len,
        "{}: prompts (<= {} chars + BOS) don't fit prompt_len {}",
        env.name(),
        env.max_prompt_chars(),
        prompt_len
    );
    assert!(
        env.max_answer_chars() + 1 <= gen_len,
        "{}: answers (<= {} chars + EOS) don't fit gen_len {}",
        env.name(),
        env.max_answer_chars(),
        gen_len
    );
    env
}

/// Deterministic held-out problem list (disjoint RNG stream from training).
pub fn heldout_problems(env: &dyn TaskEnv, seed: u64, n: usize) -> Vec<Problem> {
    // Stream tag 0xE7A1 separates eval sampling from all training streams.
    let mut rng = Pcg64::new(seed ^ 0x5eed_0f_e7a1, 0xe7a1);
    (0..n).map(|_| env.sample(&mut rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heldout_is_deterministic() {
        let env = arith::ArithEnv::standard();
        let a = heldout_problems(&env, 42, 16);
        let b = heldout_problems(&env, 42, 16);
        assert_eq!(a, b);
        let c = heldout_problems(&env, 43, 16);
        assert_ne!(a, c);
    }

    #[test]
    fn preset_envs_fit_geometry() {
        // Mirrors the python presets; panics here mean config drift.
        env_for_preset("tiny", 12, 8);
        env_for_preset("setup1", 16, 10);
        env_for_preset("setup2", 36, 12);
    }
}
