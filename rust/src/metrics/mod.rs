//! Metrics: per-step training records, evaluation records, and JSONL
//! persistence. Every figure/table reproduction reads these records.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Layout of the metric vector emitted by the train executables — must
/// match `python/compile/config.py::METRIC_NAMES`.
pub const TRAIN_METRIC_NAMES: [&str; 8] = [
    "loss",
    "entropy",
    "max_is_weight",
    "min_is_weight",
    "clipped_tokens",
    "mean_ratio",
    "grad_norm",
    "approx_kl",
];

/// Typed view over the train-executable metric vector.
#[derive(Debug, Clone, Copy, Default)]
pub struct TrainMetrics {
    pub loss: f64,
    pub entropy: f64,
    pub max_is_weight: f64,
    pub min_is_weight: f64,
    pub clipped_tokens: f64,
    pub mean_ratio: f64,
    pub grad_norm: f64,
    pub approx_kl: f64,
}

impl TrainMetrics {
    pub fn from_vector(v: &[f32]) -> TrainMetrics {
        assert_eq!(v.len(), TRAIN_METRIC_NAMES.len(), "metric vector layout drift");
        TrainMetrics {
            loss: v[0] as f64,
            entropy: v[1] as f64,
            max_is_weight: v[2] as f64,
            min_is_weight: v[3] as f64,
            clipped_tokens: v[4] as f64,
            mean_ratio: v[5] as f64,
            grad_norm: v[6] as f64,
            approx_kl: v[7] as f64,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("loss", Json::Num(self.loss)),
            ("entropy", Json::Num(self.entropy)),
            ("max_is_weight", Json::Num(self.max_is_weight)),
            ("min_is_weight", Json::Num(self.min_is_weight)),
            ("clipped_tokens", Json::Num(self.clipped_tokens)),
            ("mean_ratio", Json::Num(self.mean_ratio)),
            ("grad_norm", Json::Num(self.grad_norm)),
            ("approx_kl", Json::Num(self.approx_kl)),
        ])
    }
}

/// One training step's full record.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: u64,
    /// Seconds since run start when the step completed.
    pub wallclock: f64,
    pub version: u64,
    /// Mean staleness d over the batch.
    pub mean_staleness: f64,
    /// Mean alpha over the batch (Eq. 4).
    pub mean_alpha: f64,
    /// Mean shaped training reward of the consumed batch.
    pub reward: f64,
    /// Mean exact-match reward of the consumed batch.
    pub reward_exact: f64,
    /// Wall-clock seconds of the proximal-policy phase (Fig. 1).
    pub prox_secs: f64,
    /// Wall-clock seconds of the train-executable call.
    pub train_secs: f64,
    /// Wall-clock seconds the trainer spent generating inline (sync method;
    /// 0 on async paths, where generation runs on worker threads).
    pub rollout_secs: f64,
    /// Wall-clock seconds the trainer was blocked in `pop_groups` waiting
    /// for admissible groups (async methods; 0 for sync). Earlier versions
    /// misreported this wait as `rollout_secs`.
    pub wait_secs: f64,
    /// Staleness distribution over the consumed batch's rows (nearest-rank
    /// percentiles; all 0 for sync where data is on-policy).
    pub staleness_p50: f64,
    pub staleness_p95: f64,
    pub staleness_max: f64,
    pub train: TrainMetrics,
}

impl StepRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("step".into())),
            ("step", Json::Num(self.step as f64)),
            ("wallclock", Json::Num(self.wallclock)),
            ("version", Json::Num(self.version as f64)),
            ("mean_staleness", Json::Num(self.mean_staleness)),
            ("mean_alpha", Json::Num(self.mean_alpha)),
            ("reward", Json::Num(self.reward)),
            ("reward_exact", Json::Num(self.reward_exact)),
            ("prox_secs", Json::Num(self.prox_secs)),
            ("train_secs", Json::Num(self.train_secs)),
            ("rollout_secs", Json::Num(self.rollout_secs)),
            ("wait_secs", Json::Num(self.wait_secs)),
            ("staleness_p50", Json::Num(self.staleness_p50)),
            ("staleness_p95", Json::Num(self.staleness_p95)),
            ("staleness_max", Json::Num(self.staleness_max)),
            ("train", self.train.to_json()),
        ])
    }
}

/// One held-out evaluation pass.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: u64,
    pub wallclock: f64,
    /// Strict exact-match mean reward over the held-out prompts.
    pub eval_reward: f64,
    pub n_prompts: usize,
}

impl EvalRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("eval".into())),
            ("step", Json::Num(self.step as f64)),
            ("wallclock", Json::Num(self.wallclock)),
            ("eval_reward", Json::Num(self.eval_reward)),
            ("n_prompts", Json::Num(self.n_prompts as f64)),
        ])
    }
}

/// Collects records in memory and (optionally) streams them to a JSONL
/// file as the run progresses.
#[derive(Debug)]
pub struct MetricsLogger {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    writer: Option<BufWriter<File>>,
    echo: bool,
    /// First write/flush error the JSONL stream hit, if any. In-memory
    /// records stay intact either way; the coordinator surfaces this once
    /// at shutdown instead of the stream silently losing lines.
    io_error: Option<String>,
}

impl MetricsLogger {
    pub fn in_memory() -> MetricsLogger {
        MetricsLogger { steps: vec![], evals: vec![], writer: None, echo: false, io_error: None }
    }

    pub fn to_file(path: &Path, echo: bool) -> Result<MetricsLogger> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
        Ok(MetricsLogger {
            steps: vec![],
            evals: vec![],
            writer: Some(BufWriter::new(f)),
            echo,
            io_error: None,
        })
    }

    fn emit(&mut self, j: &Json) {
        let Some(w) = &mut self.writer else { return };
        let res = writeln!(w, "{}", j.dump()).and_then(|()| w.flush());
        if let Err(e) = res {
            if self.io_error.is_none() {
                self.io_error = Some(e.to_string());
            }
        }
    }

    /// First I/O error the JSONL stream hit (None if all writes landed).
    pub fn io_error(&self) -> Option<&str> {
        self.io_error.as_deref()
    }

    pub fn log_step(&mut self, rec: StepRecord) {
        if self.echo {
            eprintln!(
                "[step {:>4}] loss={:+.4} reward={:.3} exact={:.3} ent={:.3} \
                 clip={:>4.0} d̄={:.2} ᾱ={:.2} prox={:.1}ms train={:.2}s",
                rec.step,
                rec.train.loss,
                rec.reward,
                rec.reward_exact,
                rec.train.entropy,
                rec.train.clipped_tokens,
                rec.mean_staleness,
                rec.mean_alpha,
                rec.prox_secs * 1e3,
                rec.train_secs,
            );
        }
        self.emit(&rec.to_json());
        self.steps.push(rec);
    }

    pub fn log_eval(&mut self, rec: EvalRecord) {
        if self.echo {
            eprintln!(
                "[eval @ step {:>4}] exact-match reward = {:.3} ({} prompts)",
                rec.step, rec.eval_reward, rec.n_prompts
            );
        }
        self.emit(&rec.to_json());
        self.evals.push(rec);
    }

    /// Final-run summary used by Table 1 and the examples.
    pub fn summary(&self) -> Json {
        let final_eval = self.evals.last().map(|e| e.eval_reward).unwrap_or(f64::NAN);
        let total = self.steps.last().map(|s| s.wallclock).unwrap_or(0.0);
        let prox_total: f64 = self.steps.iter().map(|s| s.prox_secs).sum();
        Json::obj(vec![
            ("steps", Json::Num(self.steps.len() as f64)),
            ("final_eval_reward", Json::Num(final_eval)),
            ("total_seconds", Json::Num(total)),
            ("prox_seconds_total", Json::Num(prox_total)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64) -> StepRecord {
        StepRecord {
            step,
            wallclock: step as f64,
            version: step,
            mean_staleness: 1.0,
            mean_alpha: 0.5,
            reward: 0.4,
            reward_exact: 0.3,
            prox_secs: 0.001,
            train_secs: 0.2,
            rollout_secs: 0.0,
            wait_secs: 0.05,
            staleness_p50: 1.0,
            staleness_p95: 2.0,
            staleness_max: 2.0,
            train: TrainMetrics::from_vector(&[0.1, 2.0, 1.5, 0.5, 10.0, 1.0, 0.9, 0.01]),
        }
    }

    #[test]
    fn metric_vector_layout() {
        let m = TrainMetrics::from_vector(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert_eq!(m.loss, 1.0);
        assert_eq!(m.approx_kl, 8.0);
    }

    #[test]
    #[should_panic(expected = "layout drift")]
    fn wrong_length_panics() {
        TrainMetrics::from_vector(&[1.0, 2.0]);
    }

    #[test]
    fn jsonl_roundtrip() {
        let dir = std::env::temp_dir().join(format!("a3po-metrics-{}", std::process::id()));
        let path = dir.join("m.jsonl");
        let mut log = MetricsLogger::to_file(&path, false).unwrap();
        log.log_step(rec(1));
        log.log_eval(EvalRecord { step: 1, wallclock: 1.0, eval_reward: 0.5, n_prompts: 8 });
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let j = Json::parse(lines[0]).unwrap();
        assert_eq!(j.get("kind").as_str(), Some("step"));
        assert_eq!(j.get("train").get("entropy").as_f64(), Some(2.0));
        assert_eq!(j.get("wait_secs").as_f64(), Some(0.05));
        assert_eq!(j.get("staleness_p95").as_f64(), Some(2.0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn io_errors_are_recorded_not_swallowed() {
        // /dev/full accepts the open but fails every write with ENOSPC.
        let mut log = match MetricsLogger::to_file(Path::new("/dev/full"), false) {
            Ok(l) => l,
            Err(_) => return, // environment without /dev/full: nothing to test
        };
        assert!(log.io_error().is_none());
        log.log_step(rec(1));
        assert!(log.io_error().is_some(), "failed flush must be recorded");
        // In-memory records survive the lost stream.
        assert_eq!(log.steps.len(), 1);
        // Later records don't clobber the first error.
        let first = log.io_error().unwrap().to_string();
        log.log_step(rec(2));
        assert_eq!(log.io_error().unwrap(), first);
    }

    #[test]
    fn summary_reports_final_eval() {
        let mut log = MetricsLogger::in_memory();
        log.log_step(rec(1));
        log.log_eval(EvalRecord { step: 1, wallclock: 1.0, eval_reward: 0.25, n_prompts: 4 });
        log.log_eval(EvalRecord { step: 2, wallclock: 2.0, eval_reward: 0.75, n_prompts: 4 });
        let s = log.summary();
        assert_eq!(s.get("final_eval_reward").as_f64(), Some(0.75));
    }
}
