//! # a3po — A-3PO: Approximated Proximal Policy Optimization
//!
//! A from-scratch reproduction of *"A-3PO: Accelerating Asynchronous LLM
//! Training with Staleness-aware Proximal Policy Approximation"* as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the asynchronous RL coordinator: rollout
//!   engine, staleness-tagged episode buffer, GRPO trainer, weight
//!   versioning, synthetic verifiable-math environments, metrics, and the
//!   PJRT runtime that executes AOT-compiled model artifacts.
//! * **L2 (python/compile/model.py)** — the policy transformer and the
//!   three training objectives (sync / recompute / loglinear), lowered once
//!   to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   token-logprob/entropy computation and the fused decoupled-PPO loss
//!   with A-3PO's staleness-aware interpolation (paper Eqs. 3–4).
//!
//! Python never runs at training time: `make artifacts` AOT-compiles
//! everything; the `a3po` binary (and the examples/benches) only load
//! `artifacts/<preset>/*.hlo.txt`.
//!
//! Quick start (after `make artifacts`):
//!
//! ```bash
//! cargo run --release --example quickstart
//! cargo run --release --bin a3po -- train --preset setup1 --method loglinear
//! ```

pub mod bench;
pub mod buffer;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod metrics;
pub mod rollout;
pub mod runtime;
pub mod sampler;
pub mod util;
