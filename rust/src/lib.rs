//! # a3po — A-3PO: Approximated Proximal Policy Optimization
//!
//! A from-scratch reproduction of *"A-3PO: Accelerating Asynchronous LLM
//! Training with Staleness-aware Proximal Policy Approximation"* as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the asynchronous RL coordinator: rollout
//!   engine, staleness-tagged episode buffer, GRPO trainer, weight
//!   versioning, synthetic verifiable-math environments, metrics, and a
//!   pluggable runtime that executes the model.
//! * **L2 (python/compile/model.py)** — the policy transformer and the
//!   three training objectives (sync / recompute / loglinear), lowered once
//!   to HLO text for the PJRT backend.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   token-logprob/entropy computation and the fused decoupled-PPO loss
//!   with A-3PO's staleness-aware interpolation (paper Eqs. 3–4).
//!
//! The runtime has two interchangeable backends (see [`runtime`]):
//!
//! * **native** (default) — every executable reimplemented as pure-Rust CPU
//!   math (same parameter layout, losses, and Adam as the JAX model, with a
//!   hand-written backward pass). Hermetic: no XLA install, no Python, no
//!   artifacts on disk. The built-in presets `tiny`, `setup1`, `setup2`,
//!   and `big` mirror `python/compile/config.py`.
//! * **pjrt** (cargo feature `pjrt`) — loads `artifacts/<preset>/*.hlo.txt`
//!   produced by `python/compile/aot.py` and executes them through the PJRT
//!   C API. Python never runs at training time.
//!
//! Quick start (no setup needed — native backend):
//!
//! ```bash
//! cargo run --release --example quickstart
//! cargo run --release --bin a3po -- train --preset tiny --method loglinear
//! ```

pub mod bench;
pub mod buffer;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod metrics;
pub mod rollout;
pub mod runtime;
pub mod sampler;
pub mod trace;
pub mod util;
