//! Run-level configuration for the Rust coordinator.
//!
//! Model geometry (shapes, batch sizes, vocab) is *not* configured here — it
//! is read from the artifact manifest so the coordinator can never disagree
//! with what was AOT-compiled. This module holds the knobs that live purely
//! on the Rust side: method selection, staleness control, worker counts,
//! schedules, and paths.

use crate::util::cli::Parsed;

/// The three policy-optimisation methods evaluated in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Synchronous GRPO — coupled loss, rollout/train alternate (baseline).
    Sync,
    /// Decoupled PPO with explicit proximal recomputation (Hilton et al.).
    Recompute,
    /// A-3PO: staleness-aware log-linear proximal approximation (ours).
    Loglinear,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method, String> {
        match s {
            "sync" => Ok(Method::Sync),
            "recompute" => Ok(Method::Recompute),
            "loglinear" | "a3po" => Ok(Method::Loglinear),
            other => Err(format!(
                "unknown method {other:?} (expected sync|recompute|loglinear)"
            )),
        }
    }

    /// Name of the train executable in the artifact manifest.
    pub fn executable(&self) -> &'static str {
        match self {
            Method::Sync => "train_sync",
            Method::Recompute => "train_recompute",
            Method::Loglinear => "train_loglinear",
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Method::Sync => "sync",
            Method::Recompute => "recompute",
            Method::Loglinear => "loglinear",
        }
    }

    /// Asynchronous methods decouple rollout from training; sync barriers.
    pub fn is_async(&self) -> bool {
        !matches!(self, Method::Sync)
    }

    pub const ALL: [Method; 3] = [Method::Sync, Method::Recompute, Method::Loglinear];
}

/// Staleness-control policy for the episode buffer (AReaL-style).
#[derive(Debug, Clone, Copy)]
pub struct StalenessPolicy {
    /// Episodes with version lag `d > max_staleness` are dropped.
    pub max_staleness: u64,
    /// Cap on buffered-but-unconsumed episodes (backpressure): rollout
    /// workers stall when the buffer holds this many sequences.
    pub max_buffered: usize,
}

impl Default for StalenessPolicy {
    fn default() -> Self {
        StalenessPolicy { max_staleness: 8, max_buffered: 512 }
    }
}

/// α schedule variants (the paper uses `InverseD`; the others power the
/// ablation bench `staleness_sweep`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AlphaSchedule {
    /// Paper Eq. 4: α = 0 if d = 0 else 1/d.
    InverseD,
    /// α = 0 if d = 0 else 1/d².  (decays faster toward the target policy)
    InverseD2,
    /// Constant α for d ≥ 1 (ignores how stale the data actually is).
    Constant(f64),
    /// α = 1 for d ≥ 1 — anchor at the behaviour policy (coupled-like).
    Behaviour,
}

impl AlphaSchedule {
    pub fn parse(s: &str) -> Result<AlphaSchedule, String> {
        match s {
            "inverse_d" | "1/d" => Ok(AlphaSchedule::InverseD),
            "inverse_d2" | "1/d2" => Ok(AlphaSchedule::InverseD2),
            "behaviour" | "behavior" => Ok(AlphaSchedule::Behaviour),
            other => other
                .strip_prefix("const:")
                .and_then(|v| v.parse::<f64>().ok())
                .map(AlphaSchedule::Constant)
                .ok_or_else(|| format!("unknown alpha schedule {other:?}")),
        }
    }

    /// Eq. 4 (and ablation variants): α as a function of staleness d.
    pub fn alpha(&self, d: u64) -> f32 {
        if d == 0 {
            return 0.0;
        }
        match self {
            AlphaSchedule::InverseD => 1.0 / d as f32,
            AlphaSchedule::InverseD2 => 1.0 / (d * d) as f32,
            AlphaSchedule::Constant(c) => *c as f32,
            AlphaSchedule::Behaviour => 1.0,
        }
    }
}

/// Everything needed to drive one training run.
#[derive(Debug, Clone)]
pub struct RunOptions {
    pub preset: String,
    pub artifacts_dir: String,
    pub out_dir: String,
    pub method: Method,
    pub alpha_schedule: AlphaSchedule,
    pub staleness: StalenessPolicy,
    /// RL training steps (each = n_minibatch gradient updates).
    pub steps: u64,
    /// Supervised warm-start steps before RL (stands in for the pretrained
    /// instruct model of the paper's setups).
    pub pretrain_steps: u64,
    /// Rollout worker threads (async methods only; sync uses 1 inline).
    pub workers: usize,
    /// Evaluate on the held-out prompt set every this many steps.
    pub eval_every: u64,
    /// Number of held-out prompts per evaluation pass.
    pub eval_prompts: usize,
    pub seed: u64,
    /// Extra version lag injected on top of natural asynchrony — used by
    /// controlled staleness experiments and tests.
    pub inject_staleness: u64,
    /// Start from this checkpoint (path base without .json/.bin) instead of
    /// fresh init — lets one warm start be shared across method runs.
    pub init_ckpt: Option<String>,
    /// Write a Chrome-trace JSON of the run to this path (`--trace`; the
    /// `A3PO_TRACE` env var is the fallback when unset). None = tracing off.
    pub trace_path: Option<String>,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            preset: "tiny".into(),
            artifacts_dir: "artifacts".into(),
            out_dir: "runs".into(),
            method: Method::Loglinear,
            alpha_schedule: AlphaSchedule::InverseD,
            staleness: StalenessPolicy::default(),
            steps: 50,
            pretrain_steps: 0,
            workers: 2,
            eval_every: 10,
            eval_prompts: 64,
            seed: 0,
            inject_staleness: 0,
            init_ckpt: None,
            trace_path: None,
        }
    }
}

impl RunOptions {
    /// Shared CLI schema (used by the binary, examples, and benches).
    pub fn cli(program: &str, about: &str) -> crate::util::cli::Args {
        crate::util::cli::Args::new(program, about)
            .opt("preset", "tiny", "artifact preset (tiny|setup1|setup2|big)")
            .opt("artifacts", "artifacts", "artifacts directory")
            .opt("out", "runs", "output directory for metrics/checkpoints")
            .opt("method", "loglinear", "sync|recompute|loglinear")
            .opt("alpha", "inverse_d", "alpha schedule (inverse_d|inverse_d2|const:<v>|behaviour)")
            .opt("steps", "50", "RL training steps")
            .opt("pretrain-steps", "0", "supervised warm-start steps")
            .opt("workers", "2", "rollout worker threads")
            .opt("max-staleness", "8", "drop episodes older than this many versions")
            .opt("max-buffered", "512", "episode buffer backpressure bound")
            .opt("eval-every", "10", "eval cadence in steps (0 = never)")
            .opt("eval-prompts", "64", "held-out prompts per eval")
            .opt("seed", "0", "run seed")
            .opt("inject-staleness", "0", "extra artificial version lag")
            .opt_optional("init-ckpt", "checkpoint base to warm-start from")
            .opt_optional("trace", "write a Chrome-trace JSON of the run to this path")
    }

    pub fn from_parsed(p: &Parsed) -> Result<RunOptions, String> {
        Ok(RunOptions {
            preset: p.string("preset"),
            artifacts_dir: p.string("artifacts"),
            out_dir: p.string("out"),
            method: Method::parse(p.str("method"))?,
            alpha_schedule: AlphaSchedule::parse(p.str("alpha"))?,
            staleness: StalenessPolicy {
                max_staleness: p.u64("max-staleness"),
                max_buffered: p.usize("max-buffered"),
            },
            steps: p.u64("steps"),
            pretrain_steps: p.u64("pretrain-steps"),
            workers: p.usize("workers").max(1),
            eval_every: p.u64("eval-every"),
            eval_prompts: p.usize("eval-prompts"),
            seed: p.u64("seed"),
            inject_staleness: p.u64("inject-staleness"),
            init_ckpt: p.get("init-ckpt").map(String::from),
            trace_path: p.get("trace").map(String::from),
        })
    }

    pub fn artifact_dir(&self) -> String {
        format!("{}/{}", self.artifacts_dir, self.preset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.label()).unwrap(), m);
        }
        assert_eq!(Method::parse("a3po").unwrap(), Method::Loglinear);
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn alpha_matches_eq4() {
        let s = AlphaSchedule::InverseD;
        assert_eq!(s.alpha(0), 0.0);
        assert_eq!(s.alpha(1), 1.0);
        assert_eq!(s.alpha(4), 0.25);
    }

    #[test]
    fn alpha_variants() {
        assert_eq!(AlphaSchedule::InverseD2.alpha(2), 0.25);
        assert_eq!(AlphaSchedule::Constant(0.3).alpha(5), 0.3);
        assert_eq!(AlphaSchedule::Behaviour.alpha(9), 1.0);
        assert_eq!(AlphaSchedule::parse("const:0.5").unwrap(), AlphaSchedule::Constant(0.5));
    }

    #[test]
    fn cli_to_options() {
        let p = RunOptions::cli("t", "")
            .parse_from(
                ["--method", "recompute", "--steps", "7", "--max-staleness", "3"]
                    .iter()
                    .map(|s| s.to_string()),
            )
            .unwrap();
        let o = RunOptions::from_parsed(&p).unwrap();
        assert_eq!(o.method, Method::Recompute);
        assert_eq!(o.steps, 7);
        assert_eq!(o.staleness.max_staleness, 3);
        assert_eq!(o.trace_path, None);
    }

    #[test]
    fn cli_trace_path() {
        let p = RunOptions::cli("t", "")
            .parse_from(["--trace", "runs/t.json"].iter().map(|s| s.to_string()))
            .unwrap();
        let o = RunOptions::from_parsed(&p).unwrap();
        assert_eq!(o.trace_path.as_deref(), Some("runs/t.json"));
    }
}
