//! `a3po` — leader binary.
//!
//! Subcommands:
//!   train      run one training job (any method/preset)
//!   eval       evaluate a checkpoint on the Table-2 benchmark suites
//!   inspect    print a preset's artifact manifest summary
//!
//! Examples:
//!   a3po train --preset setup1 --method loglinear --steps 100 --pretrain-steps 60
//!   a3po eval  --preset setup2 --ckpt runs/setup2_loglinear
//!   a3po inspect --preset tiny

use std::path::PathBuf;

use anyhow::{bail, Result};

use a3po::config::RunOptions;
use a3po::coordinator::{self, eval::evaluate_pass_at_1};
use a3po::env::suites;
use a3po::runtime::{checkpoint, Runtime};
use a3po::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, rest)) if !c.starts_with("--") => (c.clone(), rest.to_vec()),
        _ => {
            eprintln!(
                "usage: a3po <train|eval|inspect> [options]   (try `a3po train --help`)"
            );
            std::process::exit(2);
        }
    };
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "eval" => cmd_eval(rest),
        "inspect" => cmd_inspect(rest),
        other => bail!("unknown subcommand {other:?} (train|eval|inspect)"),
    }
}

fn cmd_train(argv: Vec<String>) -> Result<()> {
    let parsed = RunOptions::cli("a3po train", "run one A-3PO training job")
        .flag("save-ckpt", "save the final parameters under --out")
        .parse_from(argv)
        .unwrap_or_else(|msg| {
            eprintln!("{msg}");
            std::process::exit(2);
        });
    let opts = RunOptions::from_parsed(&parsed).map_err(anyhow::Error::msg)?;
    let out = coordinator::run(&opts)?;
    if parsed.flag("save-ckpt") {
        let p = coordinator::save_checkpoint(&opts, &out)?;
        eprintln!("checkpoint saved to {}.{{json,bin}}", p.display());
    }
    println!("{}", out.summary_json(&opts).dump());
    Ok(())
}

fn cmd_eval(argv: Vec<String>) -> Result<()> {
    let parsed = Args::new("a3po eval", "evaluate a checkpoint on the benchmark suites")
        .opt("preset", "setup2", "artifact preset the checkpoint was trained with")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("ckpt", "", "checkpoint path base (without .json/.bin)")
        .flag("greedy", "greedy decoding instead of temperature sampling")
        .parse_from(argv)
        .unwrap_or_else(|msg| {
            eprintln!("{msg}");
            std::process::exit(2);
        });
    let preset = parsed.string("preset");
    let dir = PathBuf::from(parsed.str("artifacts")).join(&preset);
    let runtime = Runtime::load(&dir, Some(&["decode", "init"]))?;
    let geo = &runtime.manifest.preset;

    let snapshot = if parsed.str("ckpt").is_empty() {
        eprintln!("no --ckpt given: evaluating freshly initialised parameters");
        runtime.init_params(0)?
    } else {
        checkpoint::load(&PathBuf::from(parsed.str("ckpt")), &runtime.manifest)?
    };

    let decoder = runtime.decoder()?;
    println!("{:<16} {:>8} {:>16}", "suite", "n", "pass@1 ± stderr");
    for suite in suites::table2_suites() {
        let usable = suites::fitting(
            &suite,
            geo.prompt_len.saturating_sub(1),
            geo.gen_len.saturating_sub(1),
        );
        let (p, se) = evaluate_pass_at_1(
            &decoder,
            &snapshot,
            &usable.problems,
            geo,
            parsed.flag("greedy"),
        )?;
        println!(
            "{:<16} {:>8} {:>9.2}% ± {:.2}%",
            suite.name,
            usable.problems.len(),
            100.0 * p,
            100.0 * se
        );
    }
    Ok(())
}

fn cmd_inspect(argv: Vec<String>) -> Result<()> {
    let parsed = Args::new("a3po inspect", "print a preset's manifest summary")
        .opt("preset", "tiny", "artifact preset")
        .opt("artifacts", "artifacts", "artifacts directory")
        .parse_from(argv)
        .unwrap_or_else(|msg| {
            eprintln!("{msg}");
            std::process::exit(2);
        });
    let dir = PathBuf::from(parsed.str("artifacts")).join(parsed.str("preset"));
    let m = a3po::runtime::manifest_for_dir(&dir)?;
    let p = &m.preset;
    println!("preset        {}", p.name);
    println!("params        {} tensors, {} scalars", m.params.len(), p.param_count);
    println!(
        "geometry      seq={} (prompt {} + gen {}), vocab={}",
        p.seq_len, p.prompt_len, p.gen_len, p.vocab
    );
    println!(
        "batching      rollout={} (groups of {}), train={} x {} minibatches",
        p.rollout_batch, p.group_size, p.train_batch, p.n_minibatch
    );
    println!("executables:");
    for (name, e) in &m.executables {
        println!(
            "  {:<16} {:>8.2} MB HLO   {:>3} inputs, {:>3} outputs",
            name,
            e.hlo_bytes as f64 / 1e6,
            e.inputs.len(),
            e.outputs.len()
        );
    }
    Ok(())
}
