//! Bench support: a small criterion-replacement timing harness plus a
//! cached "comparison run" driver shared by the per-figure bench targets.
//!
//! Every `benches/figN_*.rs` / `benches/tableN_*.rs` binary regenerates one
//! table or figure of the paper. Training-based benches share one set of
//! runs (sync / recompute / loglinear on the same preset, same epochs —
//! exactly the paper's protocol) through an on-disk JSON cache so that
//! `cargo bench` doesn't retrain six times.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::{Method, RunOptions, StalenessPolicy};
use crate::coordinator;
use crate::util::json::Json;
use crate::util::stats::Running;
use crate::util::timer::Stopwatch;

// ---------------------------------------------------------------------------
// Micro-bench harness (criterion stand-in)

#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
}

/// Time a closure: warmup, then fixed iterations; returns distribution
/// statistics. Prints a criterion-style line.
pub fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) -> BenchStats {
    for _ in 0..iters / 10 + 1 {
        f(); // warmup
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.secs() * 1e9);
    }
    let mut r = Running::new();
    for &s in &samples {
        r.push(s);
    }
    let stats = BenchStats {
        iters,
        mean_ns: r.mean(),
        p50_ns: crate::util::stats::percentile(&samples, 50.0),
        p95_ns: crate::util::stats::percentile(&samples, 95.0),
    };
    println!(
        "{:<40} {:>12.1} ns/iter (p50 {:>10.1}, p95 {:>10.1}, n={})",
        name, stats.mean_ns, stats.p50_ns, stats.p95_ns, iters
    );
    stats
}

/// The selected kernel path (register-tile ISA, tile geometry, thread
/// count) as bench-artifact metadata, so every `BENCH_*.json` number is
/// attributable to a code path.
pub fn kernel_info_json() -> Json {
    let info = crate::runtime::native::kernels::kernel_info();
    Json::obj(vec![
        ("isa", Json::Str(info.isa.name().into())),
        ("simd_available", Json::Bool(info.simd_available)),
        ("forced_by_env", Json::Bool(info.forced_by_env)),
        ("mr", Json::Num(info.mr as f64)),
        ("nr", Json::Num(info.nr as f64)),
        ("kc", Json::Num(info.kc as f64)),
        ("threads", Json::Num(info.threads as f64)),
    ])
}

/// Write a machine-readable bench artifact (e.g. `BENCH_decode.json`),
/// creating parent directories as needed.
pub fn write_bench_json(path: &Path, j: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, j.dump()).with_context(|| format!("writing {}", path.display()))?;
    eprintln!("[bench] wrote {}", path.display());
    Ok(())
}

// ---------------------------------------------------------------------------
// Shared comparison runs (the paper's three-method protocol)

/// One method's run data as needed by the figure printers.
#[derive(Debug, Clone)]
pub struct MethodRun {
    pub method: Method,
    pub final_eval: f64,
    pub total_secs: f64,
    pub prox_mean_secs: f64,
    /// (step, wallclock, shaped reward, exact reward)
    pub reward_curve: Vec<(u64, f64, f64, f64)>,
    /// (step, entropy)
    pub entropy_curve: Vec<(u64, f64)>,
    /// (step, max_iw, min_iw)
    pub is_weight_curve: Vec<(u64, f64, f64)>,
    /// (step, clipped tokens)
    pub clip_curve: Vec<(u64, f64)>,
    /// (step, wallclock, eval reward)
    pub eval_curve: Vec<(u64, f64, f64)>,
    /// Path base of the saved checkpoint.
    pub ckpt: String,
}

impl MethodRun {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::Str(self.method.label().into())),
            ("final_eval", Json::Num(self.final_eval)),
            ("total_secs", Json::Num(self.total_secs)),
            ("prox_mean_secs", Json::Num(self.prox_mean_secs)),
            (
                "reward_curve",
                Json::Arr(
                    self.reward_curve
                        .iter()
                        .map(|(s, w, r, e)| {
                            Json::arr_f64(&[*s as f64, *w, *r, *e])
                        })
                        .collect(),
                ),
            ),
            (
                "entropy_curve",
                Json::Arr(
                    self.entropy_curve
                        .iter()
                        .map(|(s, e)| Json::arr_f64(&[*s as f64, *e]))
                        .collect(),
                ),
            ),
            (
                "is_weight_curve",
                Json::Arr(
                    self.is_weight_curve
                        .iter()
                        .map(|(s, mx, mn)| Json::arr_f64(&[*s as f64, *mx, *mn]))
                        .collect(),
                ),
            ),
            (
                "clip_curve",
                Json::Arr(
                    self.clip_curve
                        .iter()
                        .map(|(s, c)| Json::arr_f64(&[*s as f64, *c]))
                        .collect(),
                ),
            ),
            (
                "eval_curve",
                Json::Arr(
                    self.eval_curve
                        .iter()
                        .map(|(s, w, r)| Json::arr_f64(&[*s as f64, *w, *r]))
                        .collect(),
                ),
            ),
            ("ckpt", Json::Str(self.ckpt.clone())),
        ])
    }

    fn from_json(j: &Json) -> Result<MethodRun> {
        let curve = |key: &str| -> Vec<Vec<f64>> {
            j.get(key)
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|row| {
                    row.as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|v| v.as_f64())
                        .collect()
                })
                .collect()
        };
        Ok(MethodRun {
            method: Method::parse(j.get("method").as_str().unwrap_or(""))
                .map_err(anyhow::Error::msg)?,
            final_eval: j.get("final_eval").as_f64().unwrap_or(f64::NAN),
            total_secs: j.get("total_secs").as_f64().unwrap_or(0.0),
            prox_mean_secs: j.get("prox_mean_secs").as_f64().unwrap_or(0.0),
            reward_curve: curve("reward_curve")
                .iter()
                .map(|r| (r[0] as u64, r[1], r[2], r[3]))
                .collect(),
            entropy_curve: curve("entropy_curve")
                .iter()
                .map(|r| (r[0] as u64, r[1]))
                .collect(),
            is_weight_curve: curve("is_weight_curve")
                .iter()
                .map(|r| (r[0] as u64, r[1], r[2]))
                .collect(),
            clip_curve: curve("clip_curve").iter().map(|r| (r[0] as u64, r[1])).collect(),
            eval_curve: curve("eval_curve")
                .iter()
                .map(|r| (r[0] as u64, r[1], r[2]))
                .collect(),
            ckpt: j.get("ckpt").as_str().unwrap_or("").to_string(),
        })
    }
}

/// CLI shared by the training-based benches.
pub struct BenchConfig {
    pub preset: String,
    pub steps: u64,
    pub pretrain_steps: u64,
    pub seed: u64,
    pub workers: usize,
    pub force: bool,
    pub out_dir: String,
}

impl BenchConfig {
    pub fn from_env_args(name: &str, about: &str) -> BenchConfig {
        let parsed = crate::util::cli::Args::new(name, about)
            .opt("preset", "tiny", "artifact preset")
            .opt("steps", "40", "RL steps per method")
            .opt("pretrain-steps", "300", "warm-start steps")
            .opt("seed", "0", "seed")
            .opt("workers", "2", "rollout workers (async methods)")
            .opt("out", "runs/bench", "bench cache/output directory")
            .flag("force", "ignore the cache and re-run")
            // `cargo bench` passes --bench to the target binary.
            .flag("bench", "(ignored; passed by cargo bench)")
            .parse();
        BenchConfig {
            preset: parsed.string("preset"),
            steps: parsed.u64("steps"),
            pretrain_steps: parsed.u64("pretrain-steps"),
            seed: parsed.u64("seed"),
            workers: parsed.usize("workers"),
            force: parsed.flag("force"),
            out_dir: parsed.string("out"),
        }
    }

    fn cache_path(&self) -> PathBuf {
        PathBuf::from(&self.out_dir).join(format!(
            "cmp_{}_s{}_p{}_seed{}.json",
            self.preset, self.steps, self.pretrain_steps, self.seed
        ))
    }
}

/// Run (or load from cache) the three-method comparison on one preset.
pub fn comparison_runs(cfg: &BenchConfig) -> Result<Vec<MethodRun>> {
    let cache = cfg.cache_path();
    if !cfg.force {
        if let Ok(text) = std::fs::read_to_string(&cache) {
            if let Ok(j) = Json::parse(&text) {
                let runs: Result<Vec<MethodRun>> = j
                    .get("runs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(MethodRun::from_json)
                    .collect();
                if let Ok(runs) = runs {
                    if runs.len() == 3 {
                        eprintln!("[bench] using cached runs from {}", cache.display());
                        return Ok(runs);
                    }
                }
            }
        }
    }

    std::env::set_var("A3PO_QUIET", "1");

    // Warm-start ONCE and share the checkpoint across the three methods —
    // the paper's runs all begin from the same instruct model, and this
    // keeps the method comparison apples-to-apples (identical theta_0).
    let warm_base = PathBuf::from(&cfg.out_dir)
        .join(format!("warmstart_{}_p{}_seed{}", cfg.preset, cfg.pretrain_steps, cfg.seed));
    if cfg.pretrain_steps > 0 && !warm_base.with_extension("bin").exists() {
        eprintln!("[bench] warm-starting {} ({} supervised steps)…", cfg.preset, cfg.pretrain_steps);
        let opts = RunOptions {
            preset: cfg.preset.clone(),
            out_dir: cfg.out_dir.clone(),
            method: Method::Sync,
            steps: 0,
            pretrain_steps: cfg.pretrain_steps,
            eval_every: 0,
            eval_prompts: 64,
            seed: cfg.seed,
            ..Default::default()
        };
        let out = coordinator::run(&opts)?;
        crate::runtime::checkpoint::save(&warm_base, &out.runtime.manifest, &out.final_snapshot)?;
    }
    let init_ckpt = if cfg.pretrain_steps > 0 {
        Some(warm_base.to_str().unwrap().to_string())
    } else {
        None
    };

    let mut runs = Vec::new();
    for method in Method::ALL {
        eprintln!(
            "[bench] training {} / {} for {} steps…",
            cfg.preset,
            method.label(),
            cfg.steps
        );
        let opts = RunOptions {
            preset: cfg.preset.clone(),
            out_dir: cfg.out_dir.clone(),
            method,
            steps: cfg.steps,
            pretrain_steps: 0,
            init_ckpt: init_ckpt.clone(),
            workers: cfg.workers,
            eval_every: (cfg.steps / 8).max(1),
            eval_prompts: 64,
            seed: cfg.seed,
            staleness: StalenessPolicy { max_staleness: 8, max_buffered: 256 },
            ..Default::default()
        };
        let out = coordinator::run(&opts)?;
        let ckpt = coordinator::save_checkpoint(&opts, &out)?;
        runs.push(MethodRun {
            method,
            final_eval: out.final_eval,
            total_secs: out.total_secs,
            prox_mean_secs: out.phases.mean("prox"),
            reward_curve: out
                .logger
                .steps
                .iter()
                .map(|s| (s.step, s.wallclock, s.reward, s.reward_exact))
                .collect(),
            entropy_curve: out
                .logger
                .steps
                .iter()
                .map(|s| (s.step, s.train.entropy))
                .collect(),
            is_weight_curve: out
                .logger
                .steps
                .iter()
                .map(|s| (s.step, s.train.max_is_weight, s.train.min_is_weight))
                .collect(),
            clip_curve: out
                .logger
                .steps
                .iter()
                .map(|s| (s.step, s.train.clipped_tokens))
                .collect(),
            eval_curve: out
                .logger
                .evals
                .iter()
                .map(|e| (e.step, e.wallclock, e.eval_reward))
                .collect(),
            ckpt: ckpt.to_str().unwrap_or("").to_string(),
        });
    }

    if let Some(parent) = cache.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let j = Json::obj(vec![(
        "runs",
        Json::Arr(runs.iter().map(|r| r.to_json()).collect()),
    )]);
    std::fs::write(&cache, j.dump()).with_context(|| format!("writing {}", cache.display()))?;
    eprintln!("[bench] cached runs at {}", cache.display());
    Ok(runs)
}

/// Downsample a series to at most `n` points (keeps first/last).
pub fn downsample<T: Clone>(v: &[T], n: usize) -> Vec<T> {
    if v.len() <= n || n < 2 {
        return v.to_vec();
    }
    let stride = (v.len() - 1) as f64 / (n - 1) as f64;
    (0..n).map(|i| v[(i as f64 * stride).round() as usize].clone()).collect()
}

/// Load the artifact directory used by a bench config.
pub fn artifact_dir(cfg: &BenchConfig) -> PathBuf {
    Path::new("artifacts").join(&cfg.preset)
}
