//! The rollout engine: batched incremental generation through the runtime's
//! [`Decoder`] sessions, playing the role of the paper's inference engine
//! (SGLang/vLLM): it produces responses *and* their behaviour-policy
//! log-probs, tagged with the weight version that generated them.
//!
//! Generation drives a [`DecodeSession`]: the prompt window is prefilled
//! once, then each step appends exactly one token per *unfinished* row and
//! rows that hit EOS are dropped from the active batch instead of being
//! recomputed every position. On backends with KV-cache sessions (native)
//! each step costs one position of work; on others the session front end
//! falls back to the full-forward `decode` executable transparently.
//!
//! Async methods run `RolloutWorker`s on dedicated threads, continuously
//! pulling the latest published weights and pushing complete GRPO groups
//! into the `EpisodeBuffer`; the sync baseline calls `generate_batch`
//! inline between training steps.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::buffer::{Episode, EpisodeBuffer};
use crate::env::{tokenizer, verifier, Problem, TaskEnv};
use crate::runtime::{Decoder, ParamSnapshot, PresetConfig, WeightStore};
use crate::sampler::{sample, SamplerConfig};
use crate::trace;
use crate::trace::report::WorkerTelemetry;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// Monotonic GRPO group-id allocator shared by all rollout sources.
#[derive(Debug, Default)]
pub struct GroupIds(AtomicU64);

impl GroupIds {
    pub fn next_block(&self, n: u64) -> u64 {
        self.0.fetch_add(n, Ordering::Relaxed)
    }
}

/// Generate one rollout batch: `rollout_batch / group_size` prompts, each
/// with `group_size` sampled responses. Returns complete groups.
pub fn generate_batch(
    decoder: &Decoder,
    snapshot: &Arc<ParamSnapshot>,
    env: &dyn TaskEnv,
    geo: &PresetConfig,
    sampler_cfg: &SamplerConfig,
    rng: &mut Pcg64,
    group_ids: &GroupIds,
) -> Result<Vec<Vec<Episode>>> {
    let problems: Vec<Problem> =
        (0..geo.rollout_batch / geo.group_size).map(|_| env.sample(rng)).collect();
    let episodes = generate_for_problems(
        decoder,
        snapshot,
        &repeat_problems(&problems, geo.group_size),
        geo,
        sampler_cfg,
        rng,
    )?;
    // Slice the flat episode list back into groups of G.
    let base = group_ids.next_block(problems.len() as u64);
    let g = geo.group_size;
    let mut groups = Vec::with_capacity(problems.len());
    let mut it = episodes.into_iter();
    for pi in 0..problems.len() {
        let mut group = Vec::with_capacity(g);
        for _ in 0..g {
            let mut e = it.next().expect("episode count mismatch");
            e.group = base + pi as u64;
            group.push(e);
        }
        groups.push(group);
    }
    Ok(groups)
}

fn repeat_problems(problems: &[Problem], g: usize) -> Vec<Problem> {
    let mut out = Vec::with_capacity(problems.len() * g);
    for p in problems {
        for _ in 0..g {
            out.push(p.clone());
        }
    }
    out
}

/// Core generation loop over a fixed problem list (len == rollout_batch).
/// Used by both training rollouts and held-out evaluation.
pub fn generate_for_problems(
    decoder: &Decoder,
    snapshot: &Arc<ParamSnapshot>,
    problems: &[Problem],
    geo: &PresetConfig,
    sampler_cfg: &SamplerConfig,
    rng: &mut Pcg64,
) -> Result<Vec<Episode>> {
    let br = geo.rollout_batch;
    assert_eq!(problems.len(), br, "generate_for_problems needs a full batch");
    let (s, t, v) = (geo.seq_len, geo.seq_len - 1, geo.vocab);
    let pl = geo.prompt_len;

    // Full token window [br, s] (the episode record) + the prompt block
    // [br, pl] that seeds the decode session.
    let mut tokens = vec![tokenizer::PAD; br * s];
    let mut prompts = vec![tokenizer::PAD; br * pl];
    for (row, p) in problems.iter().enumerate() {
        let prompt = tokenizer::encode_prompt_padded(&p.prompt, pl);
        tokens[row * s..row * s + pl].copy_from_slice(&prompt);
        prompts[row * pl..(row + 1) * pl].copy_from_slice(&prompt);
    }
    let mut behav_logp = vec![0.0f32; br * t];
    let mut mask = vec![0.0f32; br * t];

    let mut session = decoder.start(snapshot, &prompts, br, pl)?;
    // Active rows by original index; rows leave the batch when they emit
    // EOS, so late positions run on ever-smaller batches.
    let mut active: Vec<usize> = (0..br).collect();
    for pos in pl..s {
        debug_assert_eq!(session.active_rows(), active.len());
        let mut new_tokens = Vec::with_capacity(active.len());
        let mut keep = Vec::with_capacity(active.len());
        {
            let logits = session.logits();
            for (ai, &row) in active.iter().enumerate() {
                let (tok, logp) = sample(&logits[ai * v..(ai + 1) * v], sampler_cfg, rng);
                tokens[row * s + pos] = tok;
                behav_logp[row * t + pos - 1] = logp;
                mask[row * t + pos - 1] = 1.0;
                let finished = tok == tokenizer::EOS;
                keep.push(!finished);
                if !finished {
                    new_tokens.push(tok);
                }
            }
        }
        if new_tokens.is_empty() || pos + 1 == s {
            break;
        }
        if new_tokens.len() != active.len() {
            session.retain_rows(&keep)?;
            active = active
                .iter()
                .zip(&keep)
                .filter(|(_, &k)| k)
                .map(|(&row, _)| row)
                .collect();
        }
        session.step(&new_tokens)?;
    }

    let version = snapshot.version;
    Ok((0..br)
        .map(|row| {
            let row_tokens = tokens[row * s..(row + 1) * s].to_vec();
            let text = tokenizer::decode(&row_tokens[pl..]);
            let p = &problems[row];
            Episode {
                behav_logp: behav_logp[row * t..(row + 1) * t].to_vec(),
                mask: mask[row * t..(row + 1) * t].to_vec(),
                reward: verifier::shaped_reward(&text, &p.answer),
                reward_exact: verifier::exact_reward(&text, &p.answer),
                version,
                group: 0, // assigned by the caller
                text,
                tokens: row_tokens,
                problem: p.clone(),
            }
        })
        .collect())
}

/// Handle to the async rollout worker pool.
pub struct RolloutPool {
    handles: Vec<JoinHandle<Result<WorkerTelemetry>>>,
}

impl RolloutPool {
    /// Spawn `n` workers that generate until the buffer shuts down.
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        n: usize,
        decoder: Decoder,
        store: Arc<WeightStore>,
        buffer: Arc<EpisodeBuffer>,
        env: Arc<dyn TaskEnv>,
        geo: PresetConfig,
        sampler_cfg: SamplerConfig,
        group_ids: Arc<GroupIds>,
        seed: u64,
    ) -> RolloutPool {
        let handles = (0..n)
            .map(|wid| {
                let decoder = decoder.clone();
                let store = store.clone();
                let buffer = buffer.clone();
                let env = env.clone();
                let geo = geo.clone();
                let sampler_cfg = sampler_cfg;
                let group_ids = group_ids.clone();
                std::thread::Builder::new()
                    .name(format!("rollout-{wid}"))
                    .spawn(move || -> Result<WorkerTelemetry> {
                        let mut rng = Pcg64::new(seed ^ 0x9011_0000, wid as u64 + 1);
                        let mut wt = WorkerTelemetry { worker: wid, ..Default::default() };
                        let life_sw = Stopwatch::start();
                        while !buffer.is_shutdown() {
                            let snapshot = store.latest();
                            let gen_sw = Stopwatch::start();
                            let groups = {
                                let _sp =
                                    trace::span_arg("generate", "rollout", "worker", wid as f64);
                                generate_batch(
                                    &decoder,
                                    &snapshot,
                                    env.as_ref(),
                                    &geo,
                                    &sampler_cfg,
                                    &mut rng,
                                    &group_ids,
                                )?
                            };
                            wt.generate_secs += gen_sw.secs();
                            for g in groups {
                                let push_sw = Stopwatch::start();
                                let pushed = {
                                    let _sp = trace::span("push_group", "rollout");
                                    buffer.push_group(g)
                                };
                                wt.push_secs += push_sw.secs();
                                if !pushed {
                                    wt.total_secs = life_sw.secs();
                                    return Ok(wt); // shutdown
                                }
                                wt.groups_pushed += 1;
                            }
                        }
                        wt.total_secs = life_sw.secs();
                        Ok(wt)
                    })
                    .expect("spawning rollout worker")
            })
            .collect();
        RolloutPool { handles }
    }

    /// Join all workers (call after `buffer.shutdown()`), returning each
    /// worker's lifetime accounting for the telemetry report.
    pub fn join(self) -> Result<Vec<WorkerTelemetry>> {
        let mut stats = Vec::with_capacity(self.handles.len());
        for h in self.handles {
            match h.join() {
                Ok(r) => stats.push(r?),
                Err(_) => anyhow::bail!("rollout worker panicked"),
            }
        }
        Ok(stats)
    }
}
