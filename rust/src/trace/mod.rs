//! Lock-light span/event tracing for the asynchronous pipeline.
//!
//! The paper's claim is a wall-clock claim, so the repo needs to see *where*
//! async time goes: trainer blocked in `pop_groups`, workers blocked on
//! backpressure, kernels fanning out. This module records `(name, category,
//! t_start, t_end, thread, args)` events into **thread-local buffers** —
//! no mutex, no allocation beyond the buffer's amortised growth on the hot
//! path — which drain into a global registry when a buffer fills, when the
//! owning thread exits, or at [`stop`].
//!
//! * **Zero-cost when disabled**: every entry point first checks one
//!   relaxed atomic; a disabled [`span`] constructs an inert guard and
//!   touches neither the clock nor thread-local storage.
//! * **Monotonic clock**: timestamps are microseconds since a process-wide
//!   [`Instant`] epoch pinned at the first [`start`].
//! * **Chrome `trace_event` export**: [`TraceData::write_chrome`] emits the
//!   JSON-object format (`{"traceEvents": [...]}`) that loads directly in
//!   Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`, including
//!   `thread_name` metadata so trainer/rollout-worker lanes are labelled.
//!
//! Enabling: set `A3PO_TRACE=<path>` (or `RunOptions::trace_path` /
//! `--trace <path>`) and the coordinator brackets the run with
//! [`start`]/[`stop`] and writes the file. Library users can call those
//! directly. Threads that record events must exit (or fill their buffer)
//! before [`stop`] for their tail events to be included — the coordinator
//! joins the rollout pool before exporting.

pub mod report;

use std::cell::RefCell;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Event model

/// What kind of Chrome `trace_event` an [`Event`] serialises to.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Complete span (`"ph":"X"`) with a duration.
    Span { dur_us: f64 },
    /// Instantaneous marker (`"ph":"i"`).
    Instant,
    /// Counter sample (`"ph":"C"`), e.g. buffer occupancy.
    Counter { value: f64 },
}

/// One recorded event. Names/categories are `&'static str` so recording
/// never allocates per event.
#[derive(Debug, Clone)]
pub struct Event {
    pub name: &'static str,
    pub cat: &'static str,
    /// Microseconds since the trace epoch.
    pub ts_us: f64,
    /// Recorder's trace-local thread id (assigned at first record).
    pub tid: u64,
    pub kind: EventKind,
    /// Optional single numeric argument (Chrome `"args": {key: value}`).
    pub arg: Option<(&'static str, f64)>,
}

// ---------------------------------------------------------------------------
// Global state: enabled flag, epoch, registry of drained buffers

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

#[derive(Default)]
struct Registry {
    events: Mutex<Vec<Event>>,
    /// `(tid, thread name)` in registration order; kept across [`start`]
    /// calls (tids are stable per OS thread).
    threads: Mutex<Vec<(u64, String)>>,
}

fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::default)
}

fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

/// Microseconds since the trace epoch (monotonic).
pub fn now_us() -> f64 {
    epoch().elapsed().as_secs_f64() * 1e6
}

/// Is tracing currently recording? One relaxed load — callers on hot paths
/// gate all other work behind this.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Begin recording. Clears events left from a previous trace window (thread
/// registrations persist). Pins the clock epoch on first use.
pub fn start() {
    let _ = epoch();
    registry().events.lock().unwrap().clear();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop recording and drain everything flushed so far plus the calling
/// thread's buffer. Other threads still alive keep their unflushed tail —
/// join recording threads first for a complete trace.
pub fn stop() -> TraceData {
    ENABLED.store(false, Ordering::SeqCst);
    let _ = LOCAL.try_with(|l| l.borrow_mut().flush());
    let events = std::mem::take(&mut *registry().events.lock().unwrap());
    let threads = registry().threads.lock().unwrap().clone();
    TraceData { events, threads }
}

// ---------------------------------------------------------------------------
// Thread-local recording

/// Flush to the registry when a thread's buffer reaches this many events.
const FLUSH_THRESHOLD: usize = 4096;

struct LocalBuf {
    tid: u64,
    events: Vec<Event>,
}

impl LocalBuf {
    fn register() -> LocalBuf {
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let name = std::thread::current().name().unwrap_or("thread").to_string();
        registry().threads.lock().unwrap().push((tid, name));
        LocalBuf { tid, events: Vec::new() }
    }

    fn flush(&mut self) {
        if !self.events.is_empty() {
            registry().events.lock().unwrap().append(&mut self.events);
        }
    }
}

impl Drop for LocalBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf::register());
}

fn record(mut e: Event) {
    // try_with: events fired during thread teardown (after the TLS buffer
    // dropped) are silently discarded rather than panicking.
    let _ = LOCAL.try_with(|l| {
        let mut l = l.borrow_mut();
        e.tid = l.tid;
        l.events.push(e);
        if l.events.len() >= FLUSH_THRESHOLD {
            l.flush();
        }
    });
}

// ---------------------------------------------------------------------------
// Recording API

/// RAII span: records a complete event covering its lifetime when dropped.
/// Inert (no clock read, no TLS touch) while tracing is disabled.
pub struct Span {
    name: &'static str,
    cat: &'static str,
    start_us: f64,
    arg: Option<(&'static str, f64)>,
    active: bool,
}

#[inline]
pub fn span(name: &'static str, cat: &'static str) -> Span {
    if !enabled() {
        return Span { name, cat, start_us: 0.0, arg: None, active: false };
    }
    Span { name, cat, start_us: now_us(), arg: None, active: true }
}

/// [`span`] with a numeric argument attached (e.g. step index, chunk count).
#[inline]
pub fn span_arg(name: &'static str, cat: &'static str, key: &'static str, value: f64) -> Span {
    let mut s = span(name, cat);
    if s.active {
        s.arg = Some((key, value));
    }
    s
}

impl Span {
    /// Attach/replace the span's numeric argument before it closes.
    pub fn set_arg(&mut self, key: &'static str, value: f64) {
        if self.active {
            self.arg = Some((key, value));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        // Spans open across a `stop()` are dropped, not recorded into the
        // next window with a stale epoch offset.
        if !self.active || !enabled() {
            return;
        }
        let dur_us = (now_us() - self.start_us).max(0.0);
        record(Event {
            name: self.name,
            cat: self.cat,
            ts_us: self.start_us,
            tid: 0,
            kind: EventKind::Span { dur_us },
            arg: self.arg,
        });
    }
}

/// Record an externally timed complete span (e.g. a measured condvar wait
/// where the start time is reconstructed from the measured duration).
pub fn complete_span(
    name: &'static str,
    cat: &'static str,
    start_us: f64,
    end_us: f64,
    arg: Option<(&'static str, f64)>,
) {
    if !enabled() {
        return;
    }
    record(Event {
        name,
        cat,
        ts_us: start_us,
        tid: 0,
        kind: EventKind::Span { dur_us: (end_us - start_us).max(0.0) },
        arg,
    });
}

/// Record a counter sample (rendered as a stacked area track in Perfetto).
#[inline]
pub fn counter(name: &'static str, value: f64) {
    if !enabled() {
        return;
    }
    record(Event {
        name,
        cat: "counter",
        ts_us: now_us(),
        tid: 0,
        kind: EventKind::Counter { value },
        arg: None,
    });
}

/// Record an instantaneous marker.
#[inline]
pub fn instant(name: &'static str, cat: &'static str) {
    if !enabled() {
        return;
    }
    record(Event { name, cat, ts_us: now_us(), tid: 0, kind: EventKind::Instant, arg: None });
}

// ---------------------------------------------------------------------------
// Export

/// A drained trace: every recorded event plus the thread-name table.
#[derive(Debug, Clone, Default)]
pub struct TraceData {
    pub events: Vec<Event>,
    /// `(tid, thread name)` for every thread that ever recorded.
    pub threads: Vec<(u64, String)>,
}

impl TraceData {
    /// Spans only (skips counters/instants).
    pub fn spans(&self) -> impl Iterator<Item = &Event> {
        self.events.iter().filter(|e| matches!(e.kind, EventKind::Span { .. }))
    }

    /// Distinct thread ids that recorded at least one span.
    pub fn span_tids(&self) -> std::collections::BTreeSet<u64> {
        self.spans().map(|e| e.tid).collect()
    }

    /// Chrome `trace_event` JSON-object format: thread metadata first, then
    /// events sorted by timestamp (deterministic output for a given trace).
    pub fn to_chrome_json(&self) -> Json {
        let mut arr: Vec<Json> = Vec::with_capacity(self.events.len() + self.threads.len());
        for (tid, name) in &self.threads {
            arr.push(Json::obj(vec![
                ("name", Json::Str("thread_name".into())),
                ("ph", Json::Str("M".into())),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(*tid as f64)),
                ("args", Json::obj(vec![("name", Json::Str(name.clone()))])),
            ]));
        }
        let mut events: Vec<&Event> = self.events.iter().collect();
        events.sort_by(|a, b| a.ts_us.total_cmp(&b.ts_us));
        for e in events {
            arr.push(event_json(e));
        }
        Json::obj(vec![
            ("traceEvents", Json::Arr(arr)),
            ("displayTimeUnit", Json::Str("ms".into())),
        ])
    }

    /// Serialise to a Chrome-trace JSON file (parents created as needed).
    pub fn write_chrome(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_chrome_json().dump())
            .with_context(|| format!("writing trace to {}", path.display()))
    }
}

fn event_json(e: &Event) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("name", Json::Str(e.name.into())),
        ("cat", Json::Str(e.cat.into())),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(e.tid as f64)),
        ("ts", Json::Num(e.ts_us)),
    ];
    match &e.kind {
        EventKind::Span { dur_us } => {
            pairs.push(("ph", Json::Str("X".into())));
            pairs.push(("dur", Json::Num(*dur_us)));
            if let Some((k, v)) = e.arg {
                pairs.push(("args", Json::obj(vec![(k, Json::Num(v))])));
            }
        }
        EventKind::Instant => {
            pairs.push(("ph", Json::Str("i".into())));
            pairs.push(("s", Json::Str("t".into())));
        }
        EventKind::Counter { value } => {
            pairs.push(("ph", Json::Str("C".into())));
            pairs.push(("args", Json::obj(vec![("value", Json::Num(*value))])));
        }
    }
    Json::obj(pairs)
}

#[cfg(test)]
mod tests {
    // Pure export-format tests on hand-built TraceData: no global recorder
    // state, so these can't race with recording tests in other harnesses
    // (the global-state tests live in `rust/tests/trace_telemetry.rs`).
    use super::*;

    fn data() -> TraceData {
        TraceData {
            events: vec![
                Event {
                    name: "outer",
                    cat: "test",
                    ts_us: 10.0,
                    tid: 1,
                    kind: EventKind::Span { dur_us: 100.0 },
                    arg: Some(("step", 3.0)),
                },
                Event {
                    name: "inner",
                    cat: "test",
                    ts_us: 20.0,
                    tid: 1,
                    kind: EventKind::Span { dur_us: 50.0 },
                    arg: None,
                },
                Event {
                    name: "buffer_episodes",
                    cat: "counter",
                    ts_us: 15.0,
                    tid: 2,
                    kind: EventKind::Counter { value: 8.0 },
                    arg: None,
                },
            ],
            threads: vec![(1, "main".into()), (2, "rollout-0".into())],
        }
    }

    #[test]
    fn chrome_json_roundtrips_through_parser() {
        let j = data().to_chrome_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        let events = parsed.get("traceEvents").as_arr().unwrap();
        // 2 thread_name metadata + 3 events.
        assert_eq!(events.len(), 5);
        let meta = &events[0];
        assert_eq!(meta.get("ph").as_str(), Some("M"));
        assert_eq!(meta.get("args").get("name").as_str(), Some("main"));
        // Events are ts-sorted after the metadata block.
        let names: Vec<&str> =
            events[2..].iter().map(|e| e.get("name").as_str().unwrap()).collect();
        assert_eq!(names, vec!["outer", "buffer_episodes", "inner"]);
    }

    #[test]
    fn span_fields_match_trace_event_schema() {
        let j = data().to_chrome_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        let outer = parsed
            .get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .find(|e| e.get("name").as_str() == Some("outer"))
            .unwrap();
        assert_eq!(outer.get("ph").as_str(), Some("X"));
        assert_eq!(outer.get("ts").as_f64(), Some(10.0));
        assert_eq!(outer.get("dur").as_f64(), Some(100.0));
        assert_eq!(outer.get("pid").as_f64(), Some(1.0));
        assert_eq!(outer.get("tid").as_f64(), Some(1.0));
        assert_eq!(outer.get("args").get("step").as_f64(), Some(3.0));
    }

    #[test]
    fn counter_serialises_value_in_args() {
        let j = data().to_chrome_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        let c = parsed
            .get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .find(|e| e.get("name").as_str() == Some("buffer_episodes"))
            .unwrap();
        assert_eq!(c.get("ph").as_str(), Some("C"));
        assert_eq!(c.get("args").get("value").as_f64(), Some(8.0));
    }

    #[test]
    fn span_tids_counts_only_span_threads() {
        let d = data();
        let tids = d.span_tids();
        assert!(tids.contains(&1));
        assert!(!tids.contains(&2), "counter-only thread is not a span thread");
    }
}
