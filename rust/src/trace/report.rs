//! Aggregated pipeline telemetry: the numbers behind the trace.
//!
//! Where `trace::TraceData` is the microscope (every span, Perfetto-ready),
//! [`TelemetryReport`] is the summary the coordinator attaches to
//! `RunOutput`/`summary_json`: per-worker rollout utilisation, trainer
//! starvation in `pop_groups`, worker backpressure-blocked time, buffer
//! occupancy (time series + high-water mark), and the run-level staleness
//! histogram. Built from `BufferStats`, worker-thread accounting, and the
//! coordinator's `PhaseTimer` — available whether or not tracing is on.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Per-rollout-worker accounting, returned from the worker thread on join.
#[derive(Debug, Clone, Default)]
pub struct WorkerTelemetry {
    pub worker: usize,
    /// Seconds spent inside `generate_batch` (useful work).
    pub generate_secs: f64,
    /// Seconds spent in `push_group` (includes backpressure blocking).
    pub push_secs: f64,
    /// Worker-thread lifetime in seconds.
    pub total_secs: f64,
    pub groups_pushed: u64,
}

impl WorkerTelemetry {
    /// Fraction of the worker's lifetime spent generating (vs blocked on
    /// the buffer or waiting to exit).
    pub fn utilisation(&self) -> f64 {
        if self.total_secs <= 0.0 {
            0.0
        } else {
            (self.generate_secs / self.total_secs).clamp(0.0, 1.0)
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("worker", Json::Num(self.worker as f64)),
            ("generate_secs", Json::Num(self.generate_secs)),
            ("push_secs", Json::Num(self.push_secs)),
            ("total_secs", Json::Num(self.total_secs)),
            ("groups_pushed", Json::Num(self.groups_pushed as f64)),
            ("utilisation", Json::Num(self.utilisation())),
        ])
    }
}

/// Episode-buffer accounting over a whole run.
#[derive(Debug, Clone, Default)]
pub struct BufferTelemetry {
    pub pushed_groups: u64,
    pub popped_groups: u64,
    pub dropped_stale_groups: u64,
    /// Groups still buffered at shutdown.
    pub remaining_groups: u64,
    /// Total worker time blocked on backpressure in `push_group`.
    pub push_wait_secs: f64,
    /// Total trainer time blocked in `pop_groups`.
    pub pop_wait_secs: f64,
    /// Max episodes ever simultaneously buffered.
    pub high_water_episodes: u64,
    /// Decimated `(secs since buffer creation, buffered episodes)` series.
    pub occupancy: Vec<(f64, u64)>,
}

impl BufferTelemetry {
    /// Conservation law: every pushed group is either served to the
    /// trainer, dropped as stale, or still buffered at shutdown.
    pub fn accounting_consistent(&self) -> bool {
        self.pushed_groups == self.popped_groups + self.dropped_stale_groups + self.remaining_groups
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("pushed_groups", Json::Num(self.pushed_groups as f64)),
            ("popped_groups", Json::Num(self.popped_groups as f64)),
            ("dropped_stale_groups", Json::Num(self.dropped_stale_groups as f64)),
            ("remaining_groups", Json::Num(self.remaining_groups as f64)),
            ("push_wait_secs", Json::Num(self.push_wait_secs)),
            ("pop_wait_secs", Json::Num(self.pop_wait_secs)),
            ("high_water_episodes", Json::Num(self.high_water_episodes as f64)),
            (
                "occupancy",
                Json::Arr(
                    self.occupancy
                        .iter()
                        .map(|(t, n)| Json::Arr(vec![Json::Num(*t), Json::Num(*n as f64)]))
                        .collect(),
                ),
            ),
        ])
    }
}

/// Run-level staleness histogram over every trained row (per-row `d`
/// values from assembled batches; exact counts, not sampled).
#[derive(Debug, Clone, Default)]
pub struct StalenessHistogram {
    counts: BTreeMap<u64, u64>,
    n: u64,
}

impl StalenessHistogram {
    pub fn push(&mut self, d: u64) {
        *self.counts.entry(d).or_insert(0) += 1;
        self.n += 1;
    }

    pub fn extend(&mut self, ds: &[u64]) {
        for &d in ds {
            self.push(d);
        }
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn max(&self) -> u64 {
        self.counts.keys().next_back().copied().unwrap_or(0)
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let sum: f64 = self.counts.iter().map(|(&d, &c)| d as f64 * c as f64).sum();
        sum / self.n as f64
    }

    /// Nearest-rank percentile (`p` in [0,100]) over the exact counts.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.n as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (&d, &c) in &self.counts {
            cum += c;
            if cum >= rank {
                return d as f64;
            }
        }
        self.max() as f64
    }

    pub fn counts(&self) -> &BTreeMap<u64, u64> {
        &self.counts
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::Num(self.n as f64)),
            ("mean", Json::Num(self.mean())),
            ("p50", Json::Num(self.percentile(50.0))),
            ("p95", Json::Num(self.percentile(95.0))),
            ("max", Json::Num(self.max() as f64)),
            (
                "counts",
                Json::Arr(
                    self.counts
                        .iter()
                        .map(|(&d, &c)| Json::Arr(vec![Json::Num(d as f64), Json::Num(c as f64)]))
                        .collect(),
                ),
            ),
        ])
    }
}

/// The run-level rollup the coordinator attaches to `RunOutput`.
#[derive(Debug, Clone, Default)]
pub struct TelemetryReport {
    /// Wall-clock seconds of the training loop (excludes final eval).
    pub total_secs: f64,
    /// Trainer seconds blocked in `pop_groups` waiting for admissible
    /// groups (async starvation; 0 for sync).
    pub trainer_wait_secs: f64,
    /// Trainer seconds doing step work (prox + train phases).
    pub trainer_busy_secs: f64,
    /// Generation seconds: summed worker `generate_batch` time on async
    /// paths, inline rollout time on the sync path.
    pub generation_secs: f64,
    pub workers: Vec<WorkerTelemetry>,
    pub buffer: BufferTelemetry,
    pub staleness: StalenessHistogram,
}

impl TelemetryReport {
    /// Fraction of training-loop wall clock the trainer spent starved.
    pub fn trainer_starvation_frac(&self) -> f64 {
        if self.total_secs <= 0.0 {
            0.0
        } else {
            (self.trainer_wait_secs / self.total_secs).clamp(0.0, 1.0)
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("total_secs", Json::Num(self.total_secs)),
            ("trainer_wait_secs", Json::Num(self.trainer_wait_secs)),
            ("trainer_busy_secs", Json::Num(self.trainer_busy_secs)),
            ("trainer_starvation_frac", Json::Num(self.trainer_starvation_frac())),
            ("generation_secs", Json::Num(self.generation_secs)),
            ("workers", Json::Arr(self.workers.iter().map(|w| w.to_json()).collect())),
            ("buffer", self.buffer.to_json()),
            ("staleness", self.staleness.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_nearest_rank() {
        let mut h = StalenessHistogram::default();
        h.extend(&[0, 0, 0, 1, 1, 2, 8]);
        assert_eq!(h.n(), 7);
        assert_eq!(h.percentile(50.0), 1.0);
        assert_eq!(h.percentile(95.0), 8.0);
        assert_eq!(h.max(), 8);
        assert!((h.mean() - 12.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = StalenessHistogram::default();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn buffer_accounting_identity() {
        let mut b = BufferTelemetry {
            pushed_groups: 10,
            popped_groups: 6,
            dropped_stale_groups: 3,
            remaining_groups: 1,
            ..Default::default()
        };
        assert!(b.accounting_consistent());
        b.remaining_groups = 2;
        assert!(!b.accounting_consistent());
    }

    #[test]
    fn worker_utilisation_bounds() {
        let w = WorkerTelemetry {
            worker: 0,
            generate_secs: 3.0,
            push_secs: 1.0,
            total_secs: 4.0,
            groups_pushed: 5,
        };
        assert!((w.utilisation() - 0.75).abs() < 1e-12);
        let idle = WorkerTelemetry::default();
        assert_eq!(idle.utilisation(), 0.0);
    }

    #[test]
    fn report_json_shape() {
        let mut rep =
            TelemetryReport { total_secs: 10.0, trainer_wait_secs: 2.5, ..Default::default() };
        rep.staleness.extend(&[0, 1, 1]);
        let j = rep.to_json();
        assert_eq!(j.get("trainer_starvation_frac").as_f64(), Some(0.25));
        assert_eq!(j.get("staleness").get("n").as_f64(), Some(3.0));
        // Round-trips through the serialiser.
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.get("total_secs").as_f64(), Some(10.0));
    }
}
