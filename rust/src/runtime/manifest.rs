//! Artifact manifest: the contract between a backend and the coordinator.
//!
//! The manifest records, for every executable, the exact flat positional
//! input/output signature (names, shapes, dtypes) plus the model parameter
//! order, so the coordinator can pack and unpack tensors without ever
//! re-deriving shapes. Two producers exist: `python/compile/aot.py` writes
//! `manifest.json` next to its AOT-compiled HLO (the `pjrt` backend reads it
//! here via [`Manifest::load`]), and `runtime::native` synthesises the same
//! structure in-process for the built-in presets — zero files on disk.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other:?} in manifest"),
        }
    }
}

/// Shape + dtype + name of one tensor in an executable signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j.get("name").as_str().ok_or_else(|| anyhow!("sig missing name"))?;
        let dtype = Dtype::parse(j.get("dtype").as_str().unwrap_or("f32"))?;
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("sig {name} missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim in {name}")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { name: name.to_string(), shape, dtype })
    }
}

/// One AOT-compiled executable's file + flat positional signature.
#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub hlo_bytes: u64,
}

/// Geometry the coordinator needs, echoed from the python preset.
#[derive(Debug, Clone)]
pub struct PresetConfig {
    pub name: String,
    pub vocab: usize,
    pub seq_len: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub group_size: usize,
    pub rollout_batch: usize,
    pub train_batch: usize,
    pub n_minibatch: usize,
    pub param_count: u64,
    pub lr: f64,
    pub temperature: f64,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub preset: PresetConfig,
    pub params: Vec<TensorSpec>,
    pub metric_names: Vec<String>,
    pub executables: BTreeMap<String, ExecSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first?)", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        if j.get("format").as_str() != Some("hlo-text-v1") {
            bail!("unsupported manifest format {:?}", j.get("format"));
        }
        let cfg = j.get("config");
        let model = cfg.get("model");
        let need = |v: &Json, what: &str| -> Result<usize> {
            v.as_usize().ok_or_else(|| anyhow!("manifest missing {what}"))
        };
        let preset = PresetConfig {
            name: cfg.get("name").as_str().unwrap_or("?").to_string(),
            vocab: need(model.get("vocab"), "model.vocab")?,
            seq_len: need(cfg.get("seq_len"), "seq_len")?,
            prompt_len: need(cfg.get("prompt_len"), "prompt_len")?,
            gen_len: need(cfg.get("gen_len"), "gen_len")?,
            group_size: need(cfg.get("group_size"), "group_size")?,
            rollout_batch: need(cfg.get("rollout_batch"), "rollout_batch")?,
            train_batch: need(cfg.get("train_batch"), "train_batch")?,
            n_minibatch: need(cfg.get("n_minibatch"), "n_minibatch")?,
            param_count: model.get("param_count").as_i64().unwrap_or(0) as u64,
            lr: cfg.get("lr").as_f64().unwrap_or(0.0),
            temperature: cfg.get("temperature").as_f64().unwrap_or(1.0),
        };

        let params = j
            .get("params")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing params"))?
            .iter()
            .map(TensorSpec::from_json)
            .collect::<Result<Vec<_>>>()?;

        let metric_names = j
            .get("metric_names")
            .as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default();

        let mut executables = BTreeMap::new();
        let execs = j
            .get("executables")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing executables"))?;
        for (name, e) in execs {
            let file = e.get("file").as_str().ok_or_else(|| anyhow!("{name}: no file"))?;
            let parse_sigs = |key: &str| -> Result<Vec<TensorSpec>> {
                e.get(key)
                    .as_arr()
                    .ok_or_else(|| anyhow!("{name}: no {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            executables.insert(
                name.clone(),
                ExecSpec {
                    name: name.clone(),
                    file: dir.join(file),
                    inputs: parse_sigs("inputs")?,
                    outputs: parse_sigs("outputs")?,
                    hlo_bytes: e.get("hlo_bytes").as_i64().unwrap_or(0) as u64,
                },
            );
        }

        let m = Manifest { dir: dir.to_path_buf(), preset, params, metric_names, executables };
        m.validate()?;
        Ok(m)
    }

    /// Internal consistency checks (shapes agree across executables).
    /// Applied to JSON-loaded and built-in (native) manifests alike.
    pub(crate) fn validate(&self) -> Result<()> {
        let p = &self.preset;
        if p.train_batch % p.n_minibatch != 0 {
            bail!("train_batch not divisible by n_minibatch");
        }
        if p.rollout_batch % p.group_size != 0 {
            bail!("rollout_batch not divisible by group_size");
        }
        if p.seq_len != p.prompt_len + p.gen_len {
            bail!("seq_len != prompt_len + gen_len");
        }
        for required in ["init", "decode", "prox_forward", "train_sync",
                         "train_recompute", "train_loglinear", "pretrain"] {
            if !self.executables.contains_key(required) {
                bail!("manifest missing executable {required:?}");
            }
        }
        // Train executables must lead with the parameter list.
        for name in ["train_sync", "train_recompute", "train_loglinear"] {
            let e = &self.executables[name];
            let np = self.params.len();
            if e.inputs.len() < 3 * np {
                bail!("{name}: too few inputs for params+adam state");
            }
            for (i, spec) in self.params.iter().enumerate() {
                if e.inputs[i].shape != spec.shape {
                    bail!("{name}: param {i} shape mismatch vs manifest params");
                }
            }
        }
        Ok(())
    }

    pub fn exec(&self, name: &str) -> Result<&ExecSpec> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("executable {name:?} not in manifest"))
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }
}
