//! Shared training-path types: the exportable optimiser state and the
//! spec-driven unpacker for positional train-executable outputs.
//!
//! Train executables return `params… m.… v.… step metrics [theta_logp]` in
//! manifest order. Historically the trainer unpacked that with arithmetic on
//! `outs.len()` and bare `split_off` calls; [`TrainOutputs::unpack`] instead
//! classifies every output by its [`TensorSpec::name`] against the
//! [`ExecSpec`], so a missing or extra tensor fails with a named error
//! instead of silently shifting the split points.

use anyhow::{bail, Result};

use super::manifest::ExecSpec;
use super::tensor::HostTensor;

/// The full optimiser state of one training run, exportable from either
/// train path (session [`super::backend::TrainSession::export_state`] or
/// legacy positional tensors) for checkpointing. Tensors are in manifest
/// parameter order.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    pub opt_step: i32,
    pub params: Vec<HostTensor>,
    pub adam_m: Vec<HostTensor>,
    pub adam_v: Vec<HostTensor>,
}

/// Named outputs of one positional train/pretrain executable call.
#[derive(Debug)]
pub struct TrainOutputs {
    pub params: Vec<HostTensor>,
    pub adam_m: Vec<HostTensor>,
    pub adam_v: Vec<HostTensor>,
    /// Optimiser step counter as reported by the executable.
    pub opt_step: i32,
    /// Metrics vector (layout [`crate::metrics::TRAIN_METRIC_NAMES`]).
    pub metrics: HostTensor,
    /// θ log-probs `[train_batch, gen_len]` — train executables only;
    /// `pretrain` has no use for them.
    pub theta_logp: Option<HostTensor>,
}

impl TrainOutputs {
    /// Classify `outs` by the output names in `spec`: `"step"`, `"metrics"`
    /// and `"theta_logp"` are singletons, `"m."`/`"v."` prefixes are Adam
    /// moments, everything else is a parameter tensor. (Parameter names —
    /// `embed`, `layerN.*`, `lnf_*`, … — never start with `m.`/`v.`.)
    pub fn unpack(spec: &ExecSpec, outs: Vec<HostTensor>, n_params: usize) -> Result<TrainOutputs> {
        if outs.len() != spec.outputs.len() {
            bail!(
                "{}: got {} outputs, spec declares {}",
                spec.name,
                outs.len(),
                spec.outputs.len()
            );
        }
        let mut params = Vec::with_capacity(n_params);
        let mut adam_m = Vec::with_capacity(n_params);
        let mut adam_v = Vec::with_capacity(n_params);
        let mut opt_step: Option<i32> = None;
        let mut metrics: Option<HostTensor> = None;
        let mut theta_logp: Option<HostTensor> = None;
        for (t, ospec) in outs.into_iter().zip(&spec.outputs) {
            match ospec.name.as_str() {
                "step" => opt_step = Some(t.scalar_i32_value()?),
                "metrics" => metrics = Some(t),
                "theta_logp" => theta_logp = Some(t),
                name if name.starts_with("m.") => adam_m.push(t),
                name if name.starts_with("v.") => adam_v.push(t),
                _ => params.push(t),
            }
        }
        if params.len() != n_params || adam_m.len() != n_params || adam_v.len() != n_params {
            bail!(
                "{}: output classes params/m/v counted {}/{}/{}, expected {} each",
                spec.name,
                params.len(),
                adam_m.len(),
                adam_v.len(),
                n_params
            );
        }
        let Some(opt_step) = opt_step else {
            bail!("{}: no output named \"step\"", spec.name);
        };
        let Some(metrics) = metrics else {
            bail!("{}: no output named \"metrics\"", spec.name);
        };
        Ok(TrainOutputs { params, adam_m, adam_v, opt_step, metrics, theta_logp })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Dtype, TensorSpec};

    fn t(name: &str, dtype: Dtype) -> TensorSpec {
        TensorSpec { name: name.into(), shape: vec![2], dtype }
    }

    fn spec(outputs: Vec<TensorSpec>) -> ExecSpec {
        ExecSpec {
            name: "train_test".into(),
            file: "none".into(),
            inputs: vec![],
            outputs,
            hlo_bytes: 0,
        }
    }

    fn f(v: f32) -> HostTensor {
        HostTensor::f32(vec![2], vec![v, v])
    }

    #[test]
    fn unpack_classifies_by_name() {
        let s = spec(vec![
            t("embed", Dtype::F32),
            t("m.embed", Dtype::F32),
            t("v.embed", Dtype::F32),
            TensorSpec { name: "step".into(), shape: vec![], dtype: Dtype::I32 },
            t("metrics", Dtype::F32),
            t("theta_logp", Dtype::F32),
        ]);
        let outs = vec![f(1.0), f(2.0), f(3.0), HostTensor::scalar_i32(7), f(4.0), f(5.0)];
        let u = TrainOutputs::unpack(&s, outs, 1).unwrap();
        assert_eq!(u.params, vec![f(1.0)]);
        assert_eq!(u.adam_m, vec![f(2.0)]);
        assert_eq!(u.adam_v, vec![f(3.0)]);
        assert_eq!(u.opt_step, 7);
        assert_eq!(u.metrics, f(4.0));
        assert_eq!(u.theta_logp, Some(f(5.0)));
    }

    #[test]
    fn unpack_rejects_wrong_arity() {
        let s = spec(vec![t("embed", Dtype::F32)]);
        let e = TrainOutputs::unpack(&s, vec![], 1).unwrap_err();
        assert!(e.to_string().contains("got 0 outputs"), "{e}");
    }

    #[test]
    fn unpack_rejects_missing_named_outputs() {
        // No "step"/"metrics" in the spec: count mismatch or named error.
        let s = spec(vec![
            t("embed", Dtype::F32),
            t("m.embed", Dtype::F32),
            t("v.embed", Dtype::F32),
            t("metrics", Dtype::F32),
        ]);
        let outs = vec![f(1.0), f(2.0), f(3.0), f(4.0)];
        let e = TrainOutputs::unpack(&s, outs, 1).unwrap_err();
        assert!(e.to_string().contains("no output named \"step\""), "{e}");
    }

    #[test]
    fn unpack_rejects_param_count_mismatch() {
        let s = spec(vec![
            t("embed", Dtype::F32),
            t("m.embed", Dtype::F32),
            TensorSpec { name: "step".into(), shape: vec![], dtype: Dtype::I32 },
            t("metrics", Dtype::F32),
        ]);
        let outs = vec![f(1.0), f(2.0), HostTensor::scalar_i32(1), f(3.0)];
        let e = TrainOutputs::unpack(&s, outs, 1).unwrap_err();
        assert!(e.to_string().contains("params/m/v"), "{e}");
    }
}
