//! PJRT backend (cargo feature `pjrt`): executes the AOT-compiled HLO
//! artifacts produced by `python/compile/aot.py` through the PJRT C API.
//!
//! Requires the external `xla` crate (not part of the hermetic build
//! universe) — add it to `[dependencies]` before enabling the feature.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO *text* is the interchange
//! format (jax >= 0.5 protos are rejected by xla_extension 0.5.1; the text
//! parser reassigns instruction ids).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};
use xla::{ElementType, HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::backend::{Backend, ExecutableImpl};
use super::manifest::{Dtype, ExecSpec, Manifest, TensorSpec};
use super::tensor::HostTensor;

/// Process-wide PJRT client.
///
/// SAFETY of `Send + Sync`: the underlying `TfrtCpuClient` (and PJRT client
/// API generally) is thread-safe — compilation and execution may be invoked
/// concurrently from multiple threads. The Rust wrapper types only lack the
/// auto-traits because they hold raw pointers.
pub struct Client {
    inner: PjRtClient,
}

unsafe impl Send for Client {}
unsafe impl Sync for Client {}

impl Client {
    pub fn cpu() -> Result<Arc<Client>> {
        let inner = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Client { inner }))
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    /// Load + compile an HLO-text file into a PJRT executable.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        self.inner
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }
}

fn to_literal(t: &HostTensor) -> Result<Literal> {
    fn le_bytes<T: Copy, const N: usize>(data: &[T], conv: impl Fn(T) -> [u8; N]) -> Vec<u8> {
        let mut out = Vec::with_capacity(data.len() * N);
        for &x in data {
            out.extend_from_slice(&conv(x));
        }
        out
    }
    let (ty, bytes): (ElementType, Vec<u8>) = match t {
        HostTensor::F32 { data, .. } => (ElementType::F32, le_bytes(data, f32::to_le_bytes)),
        HostTensor::I32 { data, .. } => (ElementType::S32, le_bytes(data, i32::to_le_bytes)),
    };
    Ok(Literal::create_from_shape_and_untyped_data(ty, t.shape(), &bytes)?)
}

fn from_literal(lit: &Literal, spec: &TensorSpec) -> Result<HostTensor> {
    match spec.dtype {
        Dtype::F32 => Ok(HostTensor::f32(spec.shape.clone(), lit.to_vec::<f32>()?)),
        Dtype::I32 => Ok(HostTensor::i32(spec.shape.clone(), lit.to_vec::<i32>()?)),
    }
}

/// SAFETY: PJRT loaded executables are thread-safe for concurrent Execute
/// calls (the PJRT contract); the wrapper only lacks auto-traits because of
/// raw pointers. Rollout workers share one decode executable.
struct SendExec(PjRtLoadedExecutable);
unsafe impl Send for SendExec {}
unsafe impl Sync for SendExec {}

/// One compiled HLO module bound to its signature.
///
/// Known trade-off vs the pre-backend-abstraction design: inputs (including
/// the parameter snapshot) are packed into fresh `Literal`s on every call
/// instead of kept resident across steps. If this backend's per-step packing
/// ever shows up in profiles, cache packed literals keyed on the
/// `ParamSnapshot` identity.
pub struct PjrtExecutable {
    exe: SendExec,
    spec: ExecSpec,
}

impl ExecutableImpl for PjrtExecutable {
    fn execute(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let lits = inputs.iter().map(|t| to_literal(t)).collect::<Result<Vec<_>>>()?;
        let refs: Vec<&Literal> = lits.iter().collect();
        let result = self
            .exe
            .0
            .execute::<&Literal>(&refs)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", self.spec.name))?;
        let outs = tuple.to_tuple()?;
        outs.iter()
            .zip(&self.spec.outputs)
            .map(|(l, spec)| from_literal(l, spec))
            .collect()
    }
}

/// Backend over an `artifacts/<preset>` directory.
pub struct PjrtBackend {
    dir: PathBuf,
    client: Arc<Client>,
}

impl PjrtBackend {
    pub fn new(dir: &Path) -> Result<PjrtBackend> {
        Ok(PjrtBackend { dir: dir.to_path_buf(), client: Client::cpu()? })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.dir)
    }

    fn load_executable(&self, spec: &ExecSpec) -> Result<Box<dyn ExecutableImpl>> {
        let t0 = std::time::Instant::now();
        let exe = self
            .client
            .compile_hlo_file(&spec.file)
            .with_context(|| format!("loading executable {:?}", spec.name))?;
        if std::env::var_os("A3PO_QUIET").is_none() {
            eprintln!(
                "[runtime] compiled {:<18} ({:>7.2} MB HLO) in {:.2}s",
                spec.name,
                spec.hlo_bytes as f64 / 1e6,
                t0.elapsed().as_secs_f64()
            );
        }
        Ok(Box::new(PjrtExecutable { exe: SendExec(exe), spec: spec.clone() }))
    }
}

impl std::fmt::Debug for Client {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Client({})", self.platform())
    }
}
