//! Host-side tensors — the currency of the backend-agnostic runtime.
//!
//! The coordinator assembles batches as plain `Vec<f32>`/`Vec<i32>` host
//! tensors; backends consume and produce them directly. The native backend
//! operates on the underlying slices in place; the PJRT backend (feature
//! `pjrt`) packs them into `xla::Literal`s at the call boundary.

use anyhow::{bail, Result};

use super::manifest::{Dtype, TensorSpec};

/// A host tensor: shape + typed data. Plain owned memory, `Send + Sync`.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape, data }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros(spec: &TensorSpec) -> HostTensor {
        match spec.dtype {
            Dtype::F32 => HostTensor::F32 {
                shape: spec.shape.clone(),
                data: vec![0.0; spec.elements()],
            },
            Dtype::I32 => HostTensor::I32 {
                shape: spec.shape.clone(),
                data: vec![0; spec.elements()],
            },
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => bail!("tensor is f32, expected i32"),
        }
    }

    /// Scalar i32 accessor (seed / step counters in executable signatures).
    pub fn scalar_i32_value(&self) -> Result<i32> {
        let data = self.as_i32()?;
        if data.len() != 1 {
            bail!("expected scalar, got {} elements", data.len());
        }
        Ok(data[0])
    }

    /// Validate against a manifest signature entry.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "tensor {:?}: shape {:?} != manifest {:?}",
                spec.name,
                self.shape(),
                spec.shape
            );
        }
        if self.dtype() != spec.dtype {
            bail!("tensor {:?}: dtype mismatch", spec.name);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize], dtype: Dtype) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn accessors_match_dtype() {
        let t = HostTensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.as_f32().unwrap().len(), 6);
        assert!(t.as_i32().is_err());
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.dtype(), Dtype::F32);
    }

    #[test]
    fn scalar_value_roundtrip() {
        let t = HostTensor::scalar_i32(-7);
        assert_eq!(t.scalar_i32_value().unwrap(), -7);
        assert!(HostTensor::i32(vec![2], vec![0, 1]).scalar_i32_value().is_err());
        assert!(HostTensor::scalar_f32(1.0).scalar_i32_value().is_err());
    }

    #[test]
    fn zeros_matches_spec() {
        let s = spec("z", &[4, 5], Dtype::F32);
        let z = HostTensor::zeros(&s);
        assert_eq!(z.len(), 20);
        assert!(z.check(&s).is_ok());
    }

    #[test]
    fn check_rejects_mismatch() {
        let t = HostTensor::f32(vec![2], vec![0.0; 2]);
        assert!(t.check(&spec("x", &[3], Dtype::F32)).is_err());
        assert!(t.check(&spec("x", &[2], Dtype::I32)).is_err());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn constructor_validates() {
        HostTensor::f32(vec![2, 2], vec![0.0; 3]);
    }
}
