//! Host-side tensors and conversion to/from `xla::Literal`.
//!
//! The coordinator assembles batches as plain `Vec<f32>`/`Vec<i32>` host
//! tensors; this module packs them into literals following the manifest's
//! positional signatures and unpacks executable outputs back.

use anyhow::{bail, Result};
use xla::{ElementType, Literal};

use super::manifest::{Dtype, TensorSpec};

/// A host tensor: shape + typed data.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape, data }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros(spec: &TensorSpec) -> HostTensor {
        match spec.dtype {
            Dtype::F32 => HostTensor::F32 {
                shape: spec.shape.clone(),
                data: vec![0.0; spec.elements()],
            },
            Dtype::I32 => HostTensor::I32 {
                shape: spec.shape.clone(),
                data: vec![0; spec.elements()],
            },
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => bail!("tensor is f32, expected i32"),
        }
    }

    /// Validate against a manifest signature entry.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "tensor {:?}: shape {:?} != manifest {:?}",
                spec.name,
                self.shape(),
                spec.shape
            );
        }
        if self.dtype() != spec.dtype {
            bail!("tensor {:?}: dtype mismatch", spec.name);
        }
        Ok(())
    }

    pub fn to_literal(&self) -> Result<Literal> {
        let (ty, bytes): (ElementType, &[u8]) = match self {
            HostTensor::F32 { data, .. } => (ElementType::F32, bytemuck_f32(data)),
            HostTensor::I32 { data, .. } => (ElementType::S32, bytemuck_i32(data)),
        };
        Ok(Literal::create_from_shape_and_untyped_data(ty, self.shape(), bytes)?)
    }

    pub fn from_literal(lit: &Literal, spec: &TensorSpec) -> Result<HostTensor> {
        match spec.dtype {
            Dtype::F32 => Ok(HostTensor::F32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<f32>()?,
            }),
            Dtype::I32 => Ok(HostTensor::I32 {
                shape: spec.shape.clone(),
                data: lit.to_vec::<i32>()?,
            }),
        }
    }
}

fn bytemuck_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// `xla::Literal` wrapped for cross-thread sharing.
///
/// SAFETY: a literal is plain host memory owned by the XLA runtime; all uses
/// in this crate after construction are read-only (executables *borrow*
/// literals as inputs and never mutate them), and the underlying
/// xla::Literal operations used (`to_vec`, `shape`, execute-as-argument) are
/// const on the C++ side. Mutation APIs (`copy_from`, `decompose_tuple`) are
/// never called through a `SharedLiteral`.
pub struct SharedLiteral(pub Literal);

unsafe impl Send for SharedLiteral {}
unsafe impl Sync for SharedLiteral {}

impl SharedLiteral {
    pub fn lit(&self) -> &Literal {
        &self.0
    }
}

impl std::fmt::Debug for SharedLiteral {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SharedLiteral")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize], dtype: Dtype) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn f32_roundtrip() {
        let t = HostTensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &spec("x", &[2, 3], Dtype::F32)).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn i32_roundtrip_scalar() {
        let t = HostTensor::scalar_i32(-7);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &spec("s", &[], Dtype::I32)).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[-7]);
    }

    #[test]
    fn zeros_matches_spec() {
        let s = spec("z", &[4, 5], Dtype::F32);
        let z = HostTensor::zeros(&s);
        assert_eq!(z.len(), 20);
        assert!(z.check(&s).is_ok());
    }

    #[test]
    fn check_rejects_mismatch() {
        let t = HostTensor::f32(vec![2], vec![0.0; 2]);
        assert!(t.check(&spec("x", &[3], Dtype::F32)).is_err());
        assert!(t.check(&spec("x", &[2], Dtype::I32)).is_err());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn constructor_validates() {
        HostTensor::f32(vec![2, 2], vec![0.0; 3]);
    }
}
