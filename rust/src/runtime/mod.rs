//! L3 runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them via the PJRT C API (`xla` crate). Python never runs on
//! this path.
//!
//! ```text
//! artifacts/<preset>/manifest.json   -> Manifest (signatures, param order)
//! artifacts/<preset>/<name>.hlo.txt  -> Executable (compiled once, shared)
//! ```

pub mod checkpoint;
pub mod client;
pub mod executable;
pub mod manifest;
pub mod params;
pub mod tensor;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

pub use client::Client;
pub use executable::Executable;
pub use manifest::{Dtype, ExecSpec, Manifest, PresetConfig, TensorSpec};
pub use params::{ParamSnapshot, WeightStore};
pub use tensor::{HostTensor, SharedLiteral};

/// Everything loaded for one preset: client + manifest + all executables.
pub struct Runtime {
    pub client: Arc<Client>,
    pub manifest: Manifest,
    executables: BTreeMap<String, Arc<Executable>>,
}

impl Runtime {
    /// Load a preset's artifacts, compiling every executable in the
    /// manifest. `only` restricts which executables get compiled (tests and
    /// single-method runs avoid paying for all six).
    pub fn load(dir: &Path, only: Option<&[&str]>) -> Result<Runtime> {
        let client = Client::cpu()?;
        let manifest = Manifest::load(dir)?;
        let mut executables = BTreeMap::new();
        for (name, spec) in &manifest.executables {
            if let Some(filter) = only {
                if !filter.contains(&name.as_str()) {
                    continue;
                }
            }
            executables.insert(name.clone(), Executable::load(&client, spec)?);
        }
        Ok(Runtime { client, manifest, executables })
    }

    pub fn exec(&self, name: &str) -> Result<&Arc<Executable>> {
        self.executables
            .get(name)
            .with_context(|| format!("executable {name:?} not loaded (filtered at load?)"))
    }

    pub fn has_exec(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Run `init(seed)` and wrap the resulting parameters at version 0.
    pub fn init_params(&self, seed: i32) -> Result<Arc<ParamSnapshot>> {
        let init = self.exec("init")?;
        let seed_lit = HostTensor::scalar_i32(seed).to_literal()?;
        let outs = init.run_literals(&[&seed_lit])?;
        Ok(ParamSnapshot::new(0, outs))
    }

    /// Zero-initialised Adam moment literals (one per parameter).
    pub fn zero_adam_state(&self) -> Result<Vec<xla::Literal>> {
        self.manifest
            .params
            .iter()
            .map(|spec| HostTensor::zeros(spec).to_literal())
            .collect()
    }

    /// Per-executable cumulative timing (for §Perf reports).
    pub fn exec_stats(&self) -> Vec<(String, executable::ExecStats)> {
        self.executables
            .iter()
            .map(|(name, e)| (name.clone(), e.stats()))
            .collect()
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Runtime(preset={}, {} executables)",
            self.manifest.preset.name,
            self.executables.len()
        )
    }
}
