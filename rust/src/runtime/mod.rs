//! L3 runtime: a backend-agnostic executor registry.
//!
//! A [`Runtime`] owns one preset's [`Manifest`] plus its compiled/loaded
//! executables, obtained from a [`Backend`]:
//!
//! * **native** (default, hermetic) — pure-Rust CPU math over the built-in
//!   presets (`tiny`, `setup1`, `setup2`, `big`). Nothing on disk; the
//!   manifest is synthesised in-process.
//! * **pjrt** (cargo feature `pjrt`) — AOT-compiled HLO artifacts produced
//!   by `python/compile/aot.py`:
//!
//! ```text
//! artifacts/<preset>/manifest.json   -> Manifest (signatures, param order)
//! artifacts/<preset>/<name>.hlo.txt  -> Executable (compiled once, shared)
//! ```
//!
//! [`Runtime::load`] keeps the historical artifact-directory calling
//! convention: if `manifest.json` exists in the directory it is a PJRT
//! artifact tree; otherwise the directory's file name selects a built-in
//! native preset.

pub mod backend;
pub mod checkpoint;
pub mod decode;
pub mod executable;
pub mod manifest;
pub mod native;
pub mod params;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod tensor;
pub mod train;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

pub use backend::{
    Backend, DecodeSession, DecodeSessionFactory, ExecutableImpl, TrainInputs, TrainSession,
    TrainSessionFactory, TrainStepOutput,
};
pub use decode::Decoder;
pub use executable::Executable;
pub use manifest::{Dtype, ExecSpec, Manifest, PresetConfig, TensorSpec};
pub use native::NativeBackend;
pub use params::{ParamSnapshot, WeightStore};
pub use tensor::HostTensor;
pub use train::{TrainOutputs, TrainState};

/// Everything loaded for one preset: manifest + all executables.
pub struct Runtime {
    /// Which backend produced the executables ("native" or "pjrt").
    pub backend_name: &'static str,
    pub manifest: Manifest,
    executables: BTreeMap<String, Arc<Executable>>,
    /// Incremental-decode support, if the backend has it (see
    /// [`Runtime::decoder`]).
    decode_factory: Option<Arc<dyn DecodeSessionFactory>>,
    /// Stateful-train support, if the backend has it (see
    /// [`Runtime::train_session_factory`]).
    train_factory: Option<Arc<dyn TrainSessionFactory>>,
}

impl Runtime {
    /// Load a preset by artifact directory, resolving the backend:
    /// a `manifest.json` in `dir` means PJRT artifacts; otherwise the
    /// directory's file name names a built-in native preset (no files
    /// needed — `artifacts/tiny` works on a fresh checkout).
    ///
    /// `only` restricts which executables get instantiated (tests and
    /// single-method runs avoid paying for all of them).
    pub fn load(dir: &Path, only: Option<&[&str]>) -> Result<Runtime> {
        match resolve_dir(dir)? {
            DirKind::PjrtArtifacts => Runtime::load_pjrt(dir, only),
            DirKind::NativePreset(name) => {
                let backend = NativeBackend::new(&name).with_context(|| {
                    format!("no artifacts at {} and no built-in preset", dir.display())
                })?;
                Runtime::from_backend(&backend, only)
            }
        }
    }

    #[cfg(feature = "pjrt")]
    fn load_pjrt(dir: &Path, only: Option<&[&str]>) -> Result<Runtime> {
        Runtime::from_backend(&pjrt::PjrtBackend::new(dir)?, only)
    }

    #[cfg(not(feature = "pjrt"))]
    fn load_pjrt(dir: &Path, _only: Option<&[&str]>) -> Result<Runtime> {
        anyhow::bail!(
            "{} holds AOT artifacts but this build has no `pjrt` feature; \
             rebuild with `--features pjrt` or delete the artifacts to use \
             the native backend",
            dir.display()
        )
    }

    /// Load the built-in native preset by name (bypasses path resolution).
    pub fn native(preset: &str, only: Option<&[&str]>) -> Result<Runtime> {
        Runtime::from_backend(&NativeBackend::new(preset)?, only)
    }

    /// Instantiate a runtime from any [`Backend`].
    pub fn from_backend(backend: &dyn Backend, only: Option<&[&str]>) -> Result<Runtime> {
        let manifest = backend.manifest()?;
        let mut executables = BTreeMap::new();
        for (name, spec) in &manifest.executables {
            if let Some(filter) = only {
                if !filter.contains(&name.as_str()) {
                    continue;
                }
            }
            let imp = backend
                .load_executable(spec)
                .with_context(|| format!("loading executable {name:?}"))?;
            executables.insert(name.clone(), Executable::new(spec.clone(), imp));
        }
        Ok(Runtime {
            backend_name: backend.name(),
            manifest,
            executables,
            decode_factory: backend.decode_session_factory(),
            train_factory: backend.train_session_factory(),
        })
    }

    pub fn exec(&self, name: &str) -> Result<&Arc<Executable>> {
        self.executables
            .get(name)
            .with_context(|| format!("executable {name:?} not loaded (filtered at load?)"))
    }

    /// The rollout-facing decode front end: incremental KV-cache sessions
    /// when the backend provides them, transparent full-forward fallback
    /// otherwise. Requires the `decode` executable to be loaded.
    pub fn decoder(&self) -> Result<Decoder> {
        Ok(Decoder::new(
            self.exec("decode")?.clone(),
            self.decode_factory.clone(),
            self.manifest.preset.clone(),
        ))
    }

    pub fn has_exec(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Stateful-train support, if the backend provides it. `None` means the
    /// trainer must drive the positional `train_*` executables.
    pub fn train_session_factory(&self) -> Option<Arc<dyn TrainSessionFactory>> {
        self.train_factory.clone()
    }

    /// Run `init(seed)` and wrap the resulting parameters at version 0.
    pub fn init_params(&self, seed: i32) -> Result<Arc<ParamSnapshot>> {
        let init = self.exec("init")?;
        let outs = init.run(&[HostTensor::scalar_i32(seed)])?;
        Ok(ParamSnapshot::new(0, outs))
    }

    /// Zero-initialised Adam moment tensors (one per parameter).
    pub fn zero_adam_state(&self) -> Vec<HostTensor> {
        self.manifest.params.iter().map(HostTensor::zeros).collect()
    }

    /// Per-executable cumulative timing (for §Perf reports).
    pub fn exec_stats(&self) -> Vec<(String, executable::ExecStats)> {
        self.executables
            .iter()
            .map(|(name, e)| (name.clone(), e.stats()))
            .collect()
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Runtime({}, preset={}, {} executables)",
            self.backend_name,
            self.manifest.preset.name,
            self.executables.len()
        )
    }
}

/// How an artifact directory resolves: an on-disk PJRT artifact tree, or a
/// built-in native preset named by the directory's file name. The single
/// source of truth shared by [`Runtime::load`] and [`manifest_for_dir`].
enum DirKind {
    PjrtArtifacts,
    NativePreset(String),
}

fn resolve_dir(dir: &Path) -> Result<DirKind> {
    if dir.join("manifest.json").exists() {
        return Ok(DirKind::PjrtArtifacts);
    }
    let preset = dir
        .file_name()
        .and_then(|n| n.to_str())
        .ok_or_else(|| anyhow!("cannot infer preset name from {}", dir.display()))?;
    Ok(DirKind::NativePreset(preset.to_string()))
}

/// Resolve a manifest for an artifact directory the same way
/// [`Runtime::load`] does, without instantiating executables (used by
/// `a3po inspect`).
pub fn manifest_for_dir(dir: &Path) -> Result<Manifest> {
    match resolve_dir(dir)? {
        DirKind::PjrtArtifacts => Manifest::load(dir),
        DirKind::NativePreset(name) => NativeBackend::new(&name)?.manifest(),
    }
}
