//! The backend abstraction: how a [`crate::runtime::Runtime`] obtains its
//! manifest and its executables.
//!
//! Two implementations ship today:
//!
//! * [`super::native::NativeBackend`] — pure-Rust CPU math over the built-in
//!   presets; needs nothing on disk (the hermetic default).
//! * `super::pjrt::PjrtBackend` (feature `pjrt`) — loads AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes them through
//!   the PJRT C API.
//!
//! Both sides of the boundary speak [`HostTensor`]: a backend's executable
//! receives positional inputs matching its [`ExecSpec`] signature and
//! returns positional outputs the same way.
//!
//! Backends may additionally expose **incremental decode sessions** via
//! [`Backend::decode_session_factory`]: per-layer KV caches that make each
//! generated token cost one position of work instead of a full-window
//! forward pass. Backends without that support (PJRT today) return `None`
//! and rollout falls back to the full-forward `decode` executable through
//! [`super::decode::Decoder`] — rollout code never branches on the backend.
//!
//! Symmetrically, backends may expose **stateful train sessions** via
//! [`Backend::train_session_factory`]: the session owns parameters, Adam
//! moments, and the optimiser step counter in-place, so a train step moves
//! only the batch in and metrics + θ log-probs out, plus one copy-on-publish
//! parameter snapshot — instead of round-tripping params + 2× Adam state in
//! both directions through positional executables. Backends without that
//! support return `None` and [`crate::coordinator::Trainer`] falls back to
//! the positional `train_*`/`pretrain` executables transparently.

use std::sync::Arc;

use anyhow::Result;

use super::manifest::{ExecSpec, Manifest};
use super::params::ParamSnapshot;
use super::tensor::HostTensor;
use super::train::TrainState;

/// One loaded/compiled executable. Implementations must be callable from
/// multiple threads concurrently (rollout workers share `decode`).
pub trait ExecutableImpl: Send + Sync {
    /// Execute with positional inputs; returns positional outputs.
    /// Input arity/shape validation happens in the [`super::Executable`]
    /// wrapper — implementations may assume the signature was honoured.
    fn execute(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;
}

/// One live incremental-decode session over a fixed weight snapshot.
///
/// Lifecycle: a [`DecodeSessionFactory`] prefills the prompt window and
/// returns the session with [`DecodeSession::logits`] already predicting
/// position `prompt_len`. The caller then loops: sample one token per
/// active row from `logits()`, drop rows that finished via
/// [`DecodeSession::retain_rows`], and advance the survivors with
/// [`DecodeSession::step`]. Rows advance in lockstep (same position).
pub trait DecodeSession: Send {
    /// Number of rows still being generated.
    fn active_rows(&self) -> usize;

    /// Next-token logits `[active_rows, vocab]` for the position after the
    /// last appended token. Valid after `start`/`step`; `retain_rows`
    /// compacts this buffer to the surviving rows.
    fn logits(&self) -> &[f32];

    /// Append one sampled token per active row (in current row order) and
    /// recompute `logits()` for the following position.
    fn step(&mut self, new_tokens: &[i32]) -> Result<()>;

    /// Drop finished rows: `keep[i]` corresponds to active row `i`.
    /// Surviving rows keep their relative order.
    fn retain_rows(&mut self, keep: &[bool]) -> Result<()>;
}

/// Creates [`DecodeSession`]s for one preset (stored by the `Runtime`,
/// shared across rollout workers).
pub trait DecodeSessionFactory: Send + Sync {
    /// Prefill `prompts` (`[rows, prompt_len]`, row-major) under `snapshot`
    /// and return a session whose `logits()` predicts position `prompt_len`.
    fn start(
        &self,
        snapshot: &Arc<ParamSnapshot>,
        prompts: &[i32],
        rows: usize,
        prompt_len: usize,
    ) -> Result<Box<dyn DecodeSession>>;
}

/// Borrowed views of one RL training batch, lengths in host layout:
/// `tokens` is `[batch, seq]` row-major, the per-token tensors are
/// `[batch, gen_len]`, `alpha` is `[batch]`. `prox_logp` carries the
/// anchor log-probs when the loss mode needs them (`None` for `sync`).
pub struct TrainInputs<'a> {
    pub tokens: &'a [i32],
    pub mask: &'a [f32],
    pub behav_logp: &'a [f32],
    pub adv: &'a [f32],
    pub alpha: &'a [f32],
    pub prox_logp: Option<&'a [f32]>,
}

/// What a train step hands back: the metrics vector (layout
/// [`crate::metrics::TRAIN_METRIC_NAMES`]) and, for RL steps, the θ
/// log-probs `[batch, gen_len]` that seed the next step's prox anchor.
pub struct TrainStepOutput {
    pub metrics: Vec<f32>,
    pub theta_logp: Option<Vec<f32>>,
}

/// One live training session: owns parameters, Adam `m`/`v`, and the step
/// counter, mutating them in-place each step.
///
/// Publish semantics: state lives inside the session; the trainer calls
/// [`TrainSession::snapshot_params`] after each step to obtain the single
/// copy-on-publish parameter set it hands to the `WeightStore`. Optimiser
/// moments never cross the boundary except through
/// [`TrainSession::export_state`] (checkpointing).
pub trait TrainSession: Send {
    /// Optimiser steps applied so far (after `n` RL steps on a preset with
    /// `n_minibatch` minibatches this reads `n * n_minibatch`).
    fn opt_step(&self) -> i32;

    /// One RL step over `inputs`: mutate params/moments/step in-place,
    /// return metrics + θ log-probs.
    fn train_step(&mut self, inputs: &TrainInputs<'_>) -> Result<TrainStepOutput>;

    /// One supervised warm-up step (`tokens` `[batch, seq]`, `mask`
    /// `[batch, gen_len]`); returns metrics with `theta_logp: None`.
    fn pretrain_step(&mut self, tokens: &[i32], mask: &[f32]) -> Result<TrainStepOutput>;

    /// Copy the current parameters out as host tensors in manifest order
    /// (the one per-step copy the publish path pays).
    fn snapshot_params(&self) -> Result<Vec<HostTensor>>;

    /// Copy the full optimiser state out (for checkpointing).
    fn export_state(&self) -> Result<TrainState>;
}

/// Creates [`TrainSession`]s for one preset.
pub trait TrainSessionFactory: Send + Sync {
    /// Start a session for the method named by its train executable
    /// (`"train_sync"` / `"train_recompute"` / `"train_loglinear"`),
    /// seeding parameters from `initial` with zeroed Adam moments.
    fn start(
        &self,
        train_exec: &str,
        initial: &Arc<ParamSnapshot>,
    ) -> Result<Box<dyn TrainSession>>;
}

/// A source of executables for one preset.
pub trait Backend: Send + Sync {
    /// Short backend label ("native", "pjrt") for logs and summaries.
    fn name(&self) -> &'static str;

    /// The preset's manifest: geometry, parameter order, and the signature
    /// of every executable this backend can instantiate.
    fn manifest(&self) -> Result<Manifest>;

    /// Instantiate (compile/load) one executable by its manifest spec.
    fn load_executable(&self, spec: &ExecSpec) -> Result<Box<dyn ExecutableImpl>>;

    /// Incremental-decode support. `None` (the default) means the backend
    /// only has the full-forward `decode` executable; [`super::Decoder`]
    /// then falls back transparently.
    fn decode_session_factory(&self) -> Option<Arc<dyn DecodeSessionFactory>> {
        None
    }

    /// Stateful-train support. `None` (the default) means the backend only
    /// has the positional `train_*`/`pretrain` executables;
    /// [`crate::coordinator::Trainer`] then falls back transparently.
    fn train_session_factory(&self) -> Option<Arc<dyn TrainSessionFactory>> {
        None
    }
}
