//! The backend abstraction: how a [`crate::runtime::Runtime`] obtains its
//! manifest and its executables.
//!
//! Two implementations ship today:
//!
//! * [`super::native::NativeBackend`] — pure-Rust CPU math over the built-in
//!   presets; needs nothing on disk (the hermetic default).
//! * `super::pjrt::PjrtBackend` (feature `pjrt`) — loads AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes them through
//!   the PJRT C API.
//!
//! Both sides of the boundary speak [`HostTensor`]: a backend's executable
//! receives positional inputs matching its [`ExecSpec`] signature and
//! returns positional outputs the same way.

use anyhow::Result;

use super::manifest::{ExecSpec, Manifest};
use super::tensor::HostTensor;

/// One loaded/compiled executable. Implementations must be callable from
/// multiple threads concurrently (rollout workers share `decode`).
pub trait ExecutableImpl: Send + Sync {
    /// Execute with positional inputs; returns positional outputs.
    /// Input arity/shape validation happens in the [`super::Executable`]
    /// wrapper — implementations may assume the signature was honoured.
    fn execute(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;
}

/// A source of executables for one preset.
pub trait Backend: Send + Sync {
    /// Short backend label ("native", "pjrt") for logs and summaries.
    fn name(&self) -> &'static str;

    /// The preset's manifest: geometry, parameter order, and the signature
    /// of every executable this backend can instantiate.
    fn manifest(&self) -> Result<Manifest>;

    /// Instantiate (compile/load) one executable by its manifest spec.
    fn load_executable(&self, spec: &ExecSpec) -> Result<Box<dyn ExecutableImpl>>;
}
