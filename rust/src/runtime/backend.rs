//! The backend abstraction: how a [`crate::runtime::Runtime`] obtains its
//! manifest and its executables.
//!
//! Two implementations ship today:
//!
//! * [`super::native::NativeBackend`] — pure-Rust CPU math over the built-in
//!   presets; needs nothing on disk (the hermetic default).
//! * `super::pjrt::PjrtBackend` (feature `pjrt`) — loads AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes them through
//!   the PJRT C API.
//!
//! Both sides of the boundary speak [`HostTensor`]: a backend's executable
//! receives positional inputs matching its [`ExecSpec`] signature and
//! returns positional outputs the same way.
//!
//! Backends may additionally expose **incremental decode sessions** via
//! [`Backend::decode_session_factory`]: per-layer KV caches that make each
//! generated token cost one position of work instead of a full-window
//! forward pass. Backends without that support (PJRT today) return `None`
//! and rollout falls back to the full-forward `decode` executable through
//! [`super::decode::Decoder`] — rollout code never branches on the backend.

use std::sync::Arc;

use anyhow::Result;

use super::manifest::{ExecSpec, Manifest};
use super::params::ParamSnapshot;
use super::tensor::HostTensor;

/// One loaded/compiled executable. Implementations must be callable from
/// multiple threads concurrently (rollout workers share `decode`).
pub trait ExecutableImpl: Send + Sync {
    /// Execute with positional inputs; returns positional outputs.
    /// Input arity/shape validation happens in the [`super::Executable`]
    /// wrapper — implementations may assume the signature was honoured.
    fn execute(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;
}

/// One live incremental-decode session over a fixed weight snapshot.
///
/// Lifecycle: a [`DecodeSessionFactory`] prefills the prompt window and
/// returns the session with [`DecodeSession::logits`] already predicting
/// position `prompt_len`. The caller then loops: sample one token per
/// active row from `logits()`, drop rows that finished via
/// [`DecodeSession::retain_rows`], and advance the survivors with
/// [`DecodeSession::step`]. Rows advance in lockstep (same position).
pub trait DecodeSession: Send {
    /// Number of rows still being generated.
    fn active_rows(&self) -> usize;

    /// Next-token logits `[active_rows, vocab]` for the position after the
    /// last appended token. Valid after `start`/`step`; `retain_rows`
    /// compacts this buffer to the surviving rows.
    fn logits(&self) -> &[f32];

    /// Append one sampled token per active row (in current row order) and
    /// recompute `logits()` for the following position.
    fn step(&mut self, new_tokens: &[i32]) -> Result<()>;

    /// Drop finished rows: `keep[i]` corresponds to active row `i`.
    /// Surviving rows keep their relative order.
    fn retain_rows(&mut self, keep: &[bool]) -> Result<()>;
}

/// Creates [`DecodeSession`]s for one preset (stored by the `Runtime`,
/// shared across rollout workers).
pub trait DecodeSessionFactory: Send + Sync {
    /// Prefill `prompts` (`[rows, prompt_len]`, row-major) under `snapshot`
    /// and return a session whose `logits()` predicts position `prompt_len`.
    fn start(
        &self,
        snapshot: &Arc<ParamSnapshot>,
        prompts: &[i32],
        rows: usize,
        prompt_len: usize,
    ) -> Result<Box<dyn DecodeSession>>;
}

/// A source of executables for one preset.
pub trait Backend: Send + Sync {
    /// Short backend label ("native", "pjrt") for logs and summaries.
    fn name(&self) -> &'static str;

    /// The preset's manifest: geometry, parameter order, and the signature
    /// of every executable this backend can instantiate.
    fn manifest(&self) -> Result<Manifest>;

    /// Instantiate (compile/load) one executable by its manifest spec.
    fn load_executable(&self, spec: &ExecSpec) -> Result<Box<dyn ExecutableImpl>>;

    /// Incremental-decode support. `None` (the default) means the backend
    /// only has the full-forward `decode` executable; [`super::Decoder`]
    /// then falls back transparently.
    fn decode_session_factory(&self) -> Option<Arc<dyn DecodeSessionFactory>> {
        None
    }
}
