//! Versioned model parameters.
//!
//! The trainer owns the full optimiser state (params + Adam moments) as
//! host tensors; after each training step it *publishes* the new parameters
//! to the `WeightStore`, bumping the version counter `v(pi)`. Rollout
//! workers grab the latest published snapshot at episode start — the
//! difference between the trainer's version and the snapshot's version is
//! exactly the staleness `d` of paper Eq. 4.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::tensor::HostTensor;

/// An immutable snapshot of model parameters at some version.
pub struct ParamSnapshot {
    pub version: u64,
    /// Parameter tensors in manifest order.
    pub params: Vec<HostTensor>,
}

impl ParamSnapshot {
    pub fn new(version: u64, params: Vec<HostTensor>) -> Arc<ParamSnapshot> {
        Arc::new(ParamSnapshot { version, params })
    }

    /// Borrowed views in manifest order (executable input prefix).
    pub fn tensor_refs(&self) -> Vec<&HostTensor> {
        self.params.iter().collect()
    }
}

impl std::fmt::Debug for ParamSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ParamSnapshot(v{}, {} tensors)", self.version, self.params.len())
    }
}

/// Shared latest-weights cell: trainer publishes, rollout workers read.
#[derive(Debug)]
pub struct WeightStore {
    latest: Mutex<Arc<ParamSnapshot>>,
    version: AtomicU64,
    publishes: AtomicU64,
}

impl WeightStore {
    pub fn new(initial: Arc<ParamSnapshot>) -> Arc<WeightStore> {
        let version = initial.version;
        Arc::new(WeightStore {
            latest: Mutex::new(initial),
            version: AtomicU64::new(version),
            publishes: AtomicU64::new(0),
        })
    }

    /// Publish new weights at `version`. Versions must be monotonic.
    pub fn publish(&self, snapshot: Arc<ParamSnapshot>) {
        debug_assert!(snapshot.version >= self.version.load(Ordering::Relaxed));
        self.version.store(snapshot.version, Ordering::Release);
        *self.latest.lock().unwrap() = snapshot;
        self.publishes.fetch_add(1, Ordering::Relaxed);
    }

    /// Latest published snapshot (cheap: Arc clone under a short lock).
    pub fn latest(&self) -> Arc<ParamSnapshot> {
        self.latest.lock().unwrap().clone()
    }

    /// Latest published version = `v(pi_theta)` as rollouts see it.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    pub fn publish_count(&self) -> u64 {
        self.publishes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(version: u64) -> Arc<ParamSnapshot> {
        ParamSnapshot::new(version, vec![HostTensor::scalar_f32(version as f32)])
    }

    #[test]
    fn publish_and_read() {
        let store = WeightStore::new(snap(0));
        assert_eq!(store.version(), 0);
        store.publish(snap(1));
        store.publish(snap(2));
        assert_eq!(store.version(), 2);
        assert_eq!(store.latest().version, 2);
        assert_eq!(store.publish_count(), 2);
    }

    #[test]
    fn concurrent_readers_see_monotonic_versions() {
        let store = WeightStore::new(snap(0));
        let s2 = store.clone();
        let reader = std::thread::spawn(move || {
            let mut last = 0u64;
            for _ in 0..1000 {
                let v = s2.latest().version;
                assert!(v >= last, "version went backwards: {v} < {last}");
                last = v;
            }
        });
        for v in 1..=50 {
            store.publish(snap(v));
        }
        reader.join().unwrap();
    }
}
