//! Pure-Rust policy model: the same decoder-only transformer as
//! `python/compile/model.py`, with a hand-written backward pass.
//!
//! Everything operates on flat `f32` buffers in the manifest's parameter
//! order (embed, pos_embed, per-layer [ln1, wq, wk, wv, wo, ln2, w1, b1,
//! w2, b2], lnf, unembed). The dense math lives in [`super::kernels`]
//! (shared with the incremental decode sessions in [`super::kv`], and
//! thread-parallel over rows). The forward pass caches every intermediate
//! the backward pass needs; correctness is pinned by a finite-difference
//! gradient check in this module's tests.

#![allow(clippy::needless_range_loop)]

use super::kernels::{
    self, attention_backward, attention_forward, gelu_grad, matmul_a_bt_acc, matmul_at_b_acc,
};
use crate::runtime::manifest::{Dtype, TensorSpec};
use crate::runtime::tensor::HostTensor;
use crate::util::rng::Pcg64;

/// Transformer hyper-parameters (mirrors python `ModelConfig`).
#[derive(Debug, Clone)]
pub struct Dims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

/// Per-layer parameter slot offsets within the flat parameter list
/// (shared with the KV-cache decode sessions in `super::kv`).
pub(crate) const L_LN1S: usize = 0;
pub(crate) const L_LN1B: usize = 1;
pub(crate) const L_WQ: usize = 2;
pub(crate) const L_WK: usize = 3;
pub(crate) const L_WV: usize = 4;
pub(crate) const L_WO: usize = 5;
pub(crate) const L_LN2S: usize = 6;
pub(crate) const L_LN2B: usize = 7;
pub(crate) const L_W1: usize = 8;
pub(crate) const L_B1: usize = 9;
pub(crate) const L_W2: usize = 10;
pub(crate) const L_B2: usize = 11;
const PER_LAYER: usize = 12;

impl Dims {
    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// Deterministic (name, shape) parameter list — the manifest order.
    pub fn param_specs(&self) -> Vec<TensorSpec> {
        let (d, v, s, f) = (self.d_model, self.vocab, self.max_seq, self.d_ff);
        let spec = |name: String, shape: Vec<usize>| TensorSpec {
            name,
            shape,
            dtype: Dtype::F32,
        };
        let mut out = vec![
            spec("embed".into(), vec![v, d]),
            spec("pos_embed".into(), vec![s, d]),
        ];
        for i in 0..self.n_layers {
            let p = format!("layer{i}.");
            out.push(spec(format!("{p}ln1_scale"), vec![d]));
            out.push(spec(format!("{p}ln1_bias"), vec![d]));
            out.push(spec(format!("{p}wq"), vec![d, d]));
            out.push(spec(format!("{p}wk"), vec![d, d]));
            out.push(spec(format!("{p}wv"), vec![d, d]));
            out.push(spec(format!("{p}wo"), vec![d, d]));
            out.push(spec(format!("{p}ln2_scale"), vec![d]));
            out.push(spec(format!("{p}ln2_bias"), vec![d]));
            out.push(spec(format!("{p}w1"), vec![d, f]));
            out.push(spec(format!("{p}b1"), vec![f]));
            out.push(spec(format!("{p}w2"), vec![f, d]));
            out.push(spec(format!("{p}b2"), vec![d]));
        }
        out.push(spec("lnf_scale".into(), vec![d]));
        out.push(spec("lnf_bias".into(), vec![d]));
        out.push(spec("unembed".into(), vec![d, v]));
        out
    }

    pub fn n_params(&self) -> usize {
        2 + PER_LAYER * self.n_layers + 3
    }

    /// Total scalar parameter count (the manifest's `param_count`).
    pub fn param_count(&self) -> u64 {
        self.param_specs().iter().map(|s| s.elements() as u64).sum()
    }

    pub(crate) fn layer_base(&self, layer: usize) -> usize {
        2 + PER_LAYER * layer
    }

    pub(crate) fn lnf_scale_idx(&self) -> usize {
        2 + PER_LAYER * self.n_layers
    }

    pub(crate) fn unembed_idx(&self) -> usize {
        self.lnf_scale_idx() + 2
    }

    /// Dense-GEMM FLOPs (counting `2·m·k·n` per matmul) of one forward pass
    /// over `rows` token positions: the q/k/v/o projections, the MLP pair,
    /// and the unembedding. Attention score/context products and
    /// element-wise work are excluded — benches use this as the GFLOP/s
    /// denominator, so the convention just needs to be stated and stable.
    pub fn forward_gemm_flops(&self, rows: usize) -> u64 {
        let (d, f, v) = (self.d_model as u64, self.d_ff as u64, self.vocab as u64);
        let rows = rows as u64;
        let per_layer = 4 * 2 * rows * d * d + 2 * 2 * rows * d * f;
        self.n_layers as u64 * per_layer + 2 * rows * d * v
    }
}

// ---------------------------------------------------------------------------
// Init

/// Scaled-normal init, deterministic in `seed` (same *scheme* as the python
/// model: ones for LN scales, zeros for biases, depth-scaled normals for the
/// residual-branch outputs, 0.02-scaled normals elsewhere).
pub fn init_params(dims: &Dims, seed: i32) -> Vec<HostTensor> {
    let residual_std = 0.02 / (2.0 * dims.n_layers as f64).sqrt();
    dims.param_specs()
        .iter()
        .enumerate()
        .map(|(idx, spec)| {
            let base = spec.name.rsplit('.').next().unwrap_or(&spec.name);
            let n = spec.elements();
            let data: Vec<f32> = if base.starts_with("ln") || base.ends_with("_scale") {
                vec![1.0; n]
            } else if base.ends_with("_bias") || base.starts_with('b') {
                vec![0.0; n]
            } else {
                let std = if base == "wo" || base == "w2" { residual_std } else { 0.02 };
                let mut rng = Pcg64::new(seed as i64 as u64, 0x1417 + idx as u64);
                (0..n).map(|_| (std * rng.next_normal()) as f32).collect()
            };
            HostTensor::f32(spec.shape.clone(), data)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// LayerNorm (normalisation math in `kernels`; the cache is training-only)

pub struct LnCache {
    /// The normalisation input (a copy of the residual-stream value).
    x: Vec<f32>,
    /// 1/sqrt(var + eps) per row.
    inv: Vec<f32>,
    mean: Vec<f32>,
    /// The scaled + shifted output.
    y: Vec<f32>,
}

impl LnCache {
    fn empty() -> LnCache {
        LnCache { x: Vec::new(), inv: Vec::new(), mean: Vec::new(), y: Vec::new() }
    }
}

/// LayerNorm into a reused cache: the copy of `x` and the stats buffers
/// keep their allocations across steps.
fn layernorm_into(x: &[f32], scale: &[f32], bias: &[f32], rows: usize, d: usize, c: &mut LnCache) {
    c.x.clear();
    c.x.extend_from_slice(x);
    kernels::layernorm_stats_into(x, scale, bias, rows, d, &mut c.y, &mut c.mean, &mut c.inv);
}

/// Backward of [`layernorm_into`]: writes `dx` into a reused buffer and
/// accumulates `dscale`/`dbias`.
#[allow(clippy::too_many_arguments)]
fn layernorm_backward_into(
    cache: &LnCache,
    scale: &[f32],
    dy: &[f32],
    rows: usize,
    d: usize,
    dscale: &mut [f32],
    dbias: &mut [f32],
    dx: &mut Vec<f32>,
) {
    kernels::reset(dx, rows * d);
    for r in 0..rows {
        let x = &cache.x[r * d..(r + 1) * d];
        let dyr = &dy[r * d..(r + 1) * d];
        let (mu, iv) = (cache.mean[r], cache.inv[r]);
        let mut m1 = 0.0f32; // mean of dxhat
        let mut m2 = 0.0f32; // mean of dxhat * xhat
        for j in 0..d {
            let xhat = (x[j] - mu) * iv;
            let dxhat = dyr[j] * scale[j];
            dscale[j] += dyr[j] * xhat;
            dbias[j] += dyr[j];
            m1 += dxhat;
            m2 += dxhat * xhat;
        }
        m1 /= d as f32;
        m2 /= d as f32;
        let out = &mut dx[r * d..(r + 1) * d];
        for j in 0..d {
            let xhat = (x[j] - mu) * iv;
            let dxhat = dyr[j] * scale[j];
            out[j] = iv * (dxhat - m1 - xhat * m2);
        }
    }
}

// ---------------------------------------------------------------------------
// Forward

pub(crate) struct LayerCache {
    ln1: LnCache,
    q: Vec<f32>,
    /// Per-position keys `[b, s, d]` — the KV sessions' prefill source.
    pub(crate) k: Vec<f32>,
    /// Per-position values `[b, s, d]` — the KV sessions' prefill source.
    pub(crate) v: Vec<f32>,
    /// Attention probabilities `[b, h, s, s]` (lower-triangular rows).
    probs: Vec<f32>,
    /// Merged-head context `[b, s, d]`.
    ctx: Vec<f32>,
    ln2: LnCache,
    /// Pre-activation `h2·w1 + b1` `[b, s, f]`.
    mlp_pre: Vec<f32>,
    /// `gelu(mlp_pre)`.
    mlp_act: Vec<f32>,
}

impl LayerCache {
    fn empty() -> LayerCache {
        LayerCache {
            ln1: LnCache::empty(),
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            probs: Vec::new(),
            ctx: Vec::new(),
            ln2: LnCache::empty(),
            mlp_pre: Vec::new(),
            mlp_act: Vec::new(),
        }
    }
}

pub struct Cache {
    b: usize,
    s: usize,
    /// Residual stream `[b, s, d]` after the last layer (pre-lnf).
    x: Vec<f32>,
    pub(crate) layers: Vec<LayerCache>,
    lnf: LnCache,
    /// Logits `[b, s, v]`.
    pub logits: Vec<f32>,
    /// Scratch shared by the attention/MLP output projections.
    tmp: Vec<f32>,
}

impl Cache {
    /// An empty workspace for `dims`: every buffer grows on first use and
    /// keeps its allocation across [`forward_into`] calls.
    pub fn empty(dims: &Dims) -> Cache {
        Cache {
            b: 0,
            s: 0,
            x: Vec::new(),
            layers: (0..dims.n_layers).map(|_| LayerCache::empty()).collect(),
            lnf: LnCache::empty(),
            logits: Vec::new(),
            tmp: Vec::new(),
        }
    }
}

/// Full forward pass over a `[b, s]` token window.
pub fn forward(dims: &Dims, p: &[&[f32]], tokens: &[i32], b: usize, s: usize) -> Cache {
    let mut cache = Cache::empty(dims);
    forward_into(dims, p, tokens, b, s, &mut cache);
    cache
}

/// [`forward`] into a reused [`Cache`]: after the first call no buffer
/// reallocates (same geometry), and the math is bit-identical to the
/// allocating path (`matmul` itself runs the overwrite kernel). The
/// `resize` only fills on first use; warm buffers skip the zeroing sweep
/// entirely — `matmul_set` overwrites every element.
fn matmul_into(out: &mut Vec<f32>, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    out.resize(m * n, 0.0);
    kernels::matmul_set(out, a, b, m, k, n);
}

pub fn forward_into(
    dims: &Dims,
    p: &[&[f32]],
    tokens: &[i32],
    b: usize,
    s: usize,
    cache: &mut Cache,
) {
    let (d, v, f, h, hd) = (dims.d_model, dims.vocab, dims.d_ff, dims.n_heads, dims.head_dim());
    assert!(s <= dims.max_seq, "seq {s} exceeds max_seq {}", dims.max_seq);
    assert_eq!(tokens.len(), b * s);
    assert_eq!(cache.layers.len(), dims.n_layers, "cache built for different dims");
    let rows = b * s;
    cache.b = b;
    cache.s = s;
    let Cache { x, layers, lnf, logits, tmp, .. } = cache;

    // Embedding + positional.
    let embed = p[0];
    let pos_embed = p[1];
    kernels::reset(x, rows * d);
    for bi in 0..b {
        for i in 0..s {
            let tok = tokens[bi * s + i] as usize;
            debug_assert!(tok < v, "token {tok} out of vocab {v}");
            let e = &embed[tok * d..(tok + 1) * d];
            let pe = &pos_embed[i * d..(i + 1) * d];
            let out = &mut x[(bi * s + i) * d..(bi * s + i + 1) * d];
            for j in 0..d {
                out[j] = e[j] + pe[j];
            }
        }
    }
    for (layer, lc) in layers.iter_mut().enumerate() {
        let base = dims.layer_base(layer);
        layernorm_into(x, p[base + L_LN1S], p[base + L_LN1B], rows, d, &mut lc.ln1);
        // Fused q/k/v projection: one shared ln1.y micropanel pack streamed
        // through all three weight panels — bit-identical to three
        // matmul_set calls at a third of the A-pack traffic.
        lc.q.resize(rows * d, 0.0);
        lc.k.resize(rows * d, 0.0);
        lc.v.resize(rows * d, 0.0);
        kernels::matmul_set_multi(
            [lc.q.as_mut_slice(), lc.k.as_mut_slice(), lc.v.as_mut_slice()],
            &lc.ln1.y,
            [p[base + L_WQ], p[base + L_WK], p[base + L_WV]],
            rows,
            d,
            d,
        );

        // Causal multi-head attention (head-parallel kernel; it fully
        // overwrites probs and ctx, so a plain resize suffices).
        lc.probs.resize(b * h * s * s, 0.0);
        lc.ctx.resize(rows * d, 0.0);
        attention_forward(b, s, h, hd, &lc.q, &lc.k, &lc.v, &mut lc.probs, &mut lc.ctx);
        matmul_into(tmp, &lc.ctx, p[base + L_WO], rows, d, d);
        for j in 0..rows * d {
            x[j] += tmp[j];
        }

        layernorm_into(x, p[base + L_LN2S], p[base + L_LN2B], rows, d, &mut lc.ln2);
        // MLP up-projection with bias + GELU fused into the matmul epilogue:
        // one pass over the [rows, f] pre-activation instead of three.
        lc.mlp_pre.resize(rows * f, 0.0);
        lc.mlp_act.resize(rows * f, 0.0);
        kernels::matmul_set_bias_gelu(
            &mut lc.mlp_pre,
            &mut lc.mlp_act,
            &lc.ln2.y,
            p[base + L_W1],
            p[base + L_B1],
            rows,
            d,
            f,
        );
        matmul_into(tmp, &lc.mlp_act, p[base + L_W2], rows, f, d);
        let b2 = p[base + L_B2];
        for r in 0..rows {
            let xr = &mut x[r * d..(r + 1) * d];
            let mr = &tmp[r * d..(r + 1) * d];
            for j in 0..d {
                xr[j] += mr[j] + b2[j];
            }
        }
    }

    layernorm_into(x, p[dims.lnf_scale_idx()], p[dims.lnf_scale_idx() + 1], rows, d, lnf);
    matmul_into(logits, &lnf.y, p[dims.unembed_idx()], rows, d, v);
}

// ---------------------------------------------------------------------------
// Next-token log-probs / entropy / softmax (the L1-kernel counterpart)

pub struct SeqStats {
    /// Per-position next-token log-prob `[b, s-1]`.
    pub logp: Vec<f32>,
    /// Per-position distribution entropy `[b, s-1]`.
    pub entropy: Vec<f32>,
    /// Full softmax at each scored position `[b, s-1, v]` (backward needs it).
    pub probs: Vec<f32>,
}

impl SeqStats {
    pub fn empty() -> SeqStats {
        SeqStats { logp: Vec::new(), entropy: Vec::new(), probs: Vec::new() }
    }
}

/// Score positions `0..s-1`: position t predicts `tokens[:, t+1]`.
pub fn sequence_logp(dims: &Dims, cache: &Cache, tokens: &[i32]) -> SeqStats {
    let mut stats = SeqStats::empty();
    sequence_logp_into(dims, cache, tokens, &mut stats);
    stats
}

/// [`sequence_logp`] into a reused [`SeqStats`].
pub fn sequence_logp_into(dims: &Dims, cache: &Cache, tokens: &[i32], stats: &mut SeqStats) {
    let (b, s, v) = (cache.b, cache.s, dims.vocab);
    let t = s - 1;
    kernels::reset(&mut stats.logp, b * t);
    kernels::reset(&mut stats.entropy, b * t);
    kernels::reset(&mut stats.probs, b * t * v);
    let SeqStats { logp, entropy, probs } = stats;
    for bi in 0..b {
        for ti in 0..t {
            let z = &cache.logits[(bi * s + ti) * v..(bi * s + ti + 1) * v];
            let mx = z.iter().fold(f32::NEG_INFINITY, |a, &x| a.max(x));
            let mut denom = 0.0f32;
            let prow = &mut probs[(bi * t + ti) * v..(bi * t + ti + 1) * v];
            for j in 0..v {
                prow[j] = (z[j] - mx).exp();
                denom += prow[j];
            }
            let lse = denom.ln() + mx;
            let mut ent = 0.0f32;
            for j in 0..v {
                prow[j] /= denom;
                if prow[j] > 0.0 {
                    ent -= prow[j] * (z[j] - lse);
                }
            }
            let target = tokens[bi * s + ti + 1] as usize;
            logp[bi * t + ti] = z[target] - lse;
            entropy[bi * t + ti] = ent;
        }
    }
}

/// Expand a per-position log-prob gradient into a logits gradient:
/// `dlogits[b,t,:] = g · (onehot(target) − softmax)` and zero at the last
/// position (which scores nothing).
pub fn dlogits_from_dlogp(
    dims: &Dims,
    cache: &Cache,
    stats: &SeqStats,
    tokens: &[i32],
    dlogp: &[f32],
) -> Vec<f32> {
    let mut dlogits = Vec::new();
    dlogits_from_dlogp_into(dims, cache, stats, tokens, dlogp, &mut dlogits);
    dlogits
}

/// [`dlogits_from_dlogp`] into a reused buffer (re-zeroed here — the loop
/// skips masked positions and the unscored last position).
pub fn dlogits_from_dlogp_into(
    dims: &Dims,
    cache: &Cache,
    stats: &SeqStats,
    tokens: &[i32],
    dlogp: &[f32],
    dlogits: &mut Vec<f32>,
) {
    let (b, s, v) = (cache.b, cache.s, dims.vocab);
    let t = s - 1;
    assert_eq!(dlogp.len(), b * t);
    kernels::reset(dlogits, b * s * v);
    for bi in 0..b {
        for ti in 0..t {
            let g = dlogp[bi * t + ti];
            if g == 0.0 {
                continue;
            }
            let prow = &stats.probs[(bi * t + ti) * v..(bi * t + ti + 1) * v];
            let out = &mut dlogits[(bi * s + ti) * v..(bi * s + ti + 1) * v];
            for j in 0..v {
                out[j] = -g * prow[j];
            }
            let target = tokens[bi * s + ti + 1] as usize;
            out[target] += g;
        }
    }
}

// ---------------------------------------------------------------------------
// Backward

/// Reused scratch for [`backward_into`]: the residual-stream gradient, one
/// activation-width buffer, and the attention gradient buffers — sized on
/// first use, reused every step.
#[derive(Default)]
pub struct BackwardWs {
    dxf: Vec<f32>,
    dx: Vec<f32>,
    dres: Vec<f32>,
    dact: Vec<f32>,
    dh: Vec<f32>,
    dctx: Vec<f32>,
    dq: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
}

impl BackwardWs {
    pub fn new() -> BackwardWs {
        BackwardWs::default()
    }
}

/// Backprop `dlogits [b, s, v]` through the cached forward pass; returns
/// parameter gradients in manifest order.
pub fn backward(
    dims: &Dims,
    p: &[&[f32]],
    cache: &Cache,
    tokens: &[i32],
    dlogits: &[f32],
) -> Vec<Vec<f32>> {
    let specs = dims.param_specs();
    let mut grads: Vec<Vec<f32>> = specs.iter().map(|sp| vec![0.0f32; sp.elements()]).collect();
    let mut ws = BackwardWs::new();
    backward_into(dims, p, cache, tokens, dlogits, &mut grads, &mut ws);
    grads
}

/// [`backward`] into caller-owned gradient tensors (re-zeroed here) and a
/// reused [`BackwardWs`]. Accumulation order matches the allocating path
/// exactly, so results are bit-identical.
pub fn backward_into(
    dims: &Dims,
    p: &[&[f32]],
    cache: &Cache,
    tokens: &[i32],
    dlogits: &[f32],
    grads: &mut [Vec<f32>],
    ws: &mut BackwardWs,
) {
    let (d, v, f, h, hd) = (dims.d_model, dims.vocab, dims.d_ff, dims.n_heads, dims.head_dim());
    let (b, s) = (cache.b, cache.s);
    let rows = b * s;
    debug_assert_eq!(grads.len(), dims.n_params());
    for g in grads.iter_mut() {
        g.fill(0.0);
    }

    // Unembed + final LN.
    let unembed = dims.unembed_idx();
    matmul_at_b_acc(&mut grads[unembed], &cache.lnf.y, dlogits, rows, d, v);
    kernels::reset(&mut ws.dxf, rows * d);
    matmul_a_bt_acc(&mut ws.dxf, dlogits, p[unembed], rows, v, d);
    let lnf_s = dims.lnf_scale_idx();
    {
        let (gs, rest) = grads.split_at_mut(lnf_s + 1);
        let (dscale, dbias) = (gs.last_mut().unwrap(), &mut rest[0]);
        layernorm_backward_into(&cache.lnf, p[lnf_s], &ws.dxf, rows, d, dscale, dbias, &mut ws.dx);
    }

    for layer in (0..dims.n_layers).rev() {
        let base = dims.layer_base(layer);
        let lc = &cache.layers[layer];

        // --- MLP: x2 = x1 + gelu(ln2(x1)·w1 + b1)·w2 + b2 ----------------
        {
            kernels::reset(&mut ws.dact, rows * f);
            matmul_a_bt_acc(&mut ws.dact, &ws.dx, p[base + L_W2], rows, d, f);
            matmul_at_b_acc(&mut grads[base + L_W2], &lc.mlp_act, &ws.dx, rows, f, d);
            {
                let db2 = &mut grads[base + L_B2];
                for r in 0..rows {
                    let dr = &ws.dx[r * d..(r + 1) * d];
                    for j in 0..d {
                        db2[j] += dr[j];
                    }
                }
            }
            // dact becomes dpre in place (the allocating path moved it).
            for i in 0..rows * f {
                ws.dact[i] *= gelu_grad(lc.mlp_pre[i]);
            }
            {
                let db1 = &mut grads[base + L_B1];
                for r in 0..rows {
                    let dr = &ws.dact[r * f..(r + 1) * f];
                    for j in 0..f {
                        db1[j] += dr[j];
                    }
                }
            }
            matmul_at_b_acc(&mut grads[base + L_W1], &lc.ln2.y, &ws.dact, rows, d, f);
            kernels::reset(&mut ws.dh, rows * d);
            matmul_a_bt_acc(&mut ws.dh, &ws.dact, p[base + L_W1], rows, f, d);
            let (gs, gb) = {
                let (a, bpart) = grads.split_at_mut(base + L_LN2B);
                (&mut a[base + L_LN2S], &mut bpart[0])
            };
            layernorm_backward_into(
                &lc.ln2, p[base + L_LN2S], &ws.dh, rows, d, gs, gb, &mut ws.dres,
            );
            for i in 0..rows * d {
                ws.dx[i] += ws.dres[i];
            }
        }

        // --- Attention: x1 = x0 + (softmax(q·kᵀ)·v)·wo -------------------
        {
            kernels::reset(&mut ws.dctx, rows * d);
            matmul_a_bt_acc(&mut ws.dctx, &ws.dx, p[base + L_WO], rows, d, d);
            matmul_at_b_acc(&mut grads[base + L_WO], &lc.ctx, &ws.dx, rows, d, d);

            kernels::reset(&mut ws.dq, rows * d);
            kernels::reset(&mut ws.dk, rows * d);
            kernels::reset(&mut ws.dv, rows * d);
            attention_backward(
                b, s, h, hd, &lc.probs, &lc.q, &lc.k, &lc.v, &ws.dctx, &mut ws.dq, &mut ws.dk,
                &mut ws.dv,
            );

            // Fused wq/wk/wv gradient accumulation: the transposed ln1.y
            // micropanel (a strided gather) is packed once and streamed
            // through all three dq/dk/dv panels — bit-identical to three
            // matmul_at_b_acc calls.
            {
                let (gq, rest) = grads[base + L_WQ..base + L_WV + 1].split_first_mut().unwrap();
                let (gk, rest) = rest.split_first_mut().unwrap();
                let gv = &mut rest[0];
                kernels::matmul_at_b_acc_multi(
                    [gq.as_mut_slice(), gk.as_mut_slice(), gv.as_mut_slice()],
                    &lc.ln1.y,
                    [ws.dq.as_slice(), ws.dk.as_slice(), ws.dv.as_slice()],
                    rows,
                    d,
                    d,
                );
            }
            kernels::reset(&mut ws.dh, rows * d);
            matmul_a_bt_acc(&mut ws.dh, &ws.dq, p[base + L_WQ], rows, d, d);
            matmul_a_bt_acc(&mut ws.dh, &ws.dk, p[base + L_WK], rows, d, d);
            matmul_a_bt_acc(&mut ws.dh, &ws.dv, p[base + L_WV], rows, d, d);
            let (gs, gb) = {
                let (a, bpart) = grads.split_at_mut(base + L_LN1B);
                (&mut a[base + L_LN1S], &mut bpart[0])
            };
            layernorm_backward_into(
                &lc.ln1, p[base + L_LN1S], &ws.dh, rows, d, gs, gb, &mut ws.dres,
            );
            for i in 0..rows * d {
                ws.dx[i] += ws.dres[i];
            }
        }
    }

    // Embedding scatter + positional sum.
    {
        let (gembed, gpos) = {
            let (a, bpart) = grads.split_at_mut(1);
            (&mut a[0], &mut bpart[0])
        };
        for bi in 0..b {
            for i in 0..s {
                let tok = tokens[bi * s + i] as usize;
                let dr = &ws.dx[(bi * s + i) * d..(bi * s + i + 1) * d];
                let er = &mut gembed[tok * d..(tok + 1) * d];
                let pr = &mut gpos[i * d..(i + 1) * d];
                for j in 0..d {
                    er[j] += dr[j];
                    pr[j] += dr[j];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Adam (bias-corrected, global-norm clipped)

#[derive(Debug, Clone, Copy)]
pub struct AdamHp {
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    pub grad_clip: f32,
}

/// One Adam update in place. `step` is the pre-update counter (python keeps
/// the same convention: `t = step + 1`). Returns the pre-clip global norm.
pub fn adam_update(
    hp: &AdamHp,
    lr: f32,
    params: &mut [Vec<f32>],
    m: &mut [Vec<f32>],
    v: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    step: i32,
) -> f32 {
    let mut sq = 0.0f64;
    for g in grads {
        for &x in g {
            sq += (x as f64) * (x as f64);
        }
    }
    let gnorm = sq.sqrt() as f32;
    let scale = (hp.grad_clip / gnorm.max(1e-12)).min(1.0);
    let t = step + 1;
    let bc1 = 1.0 - hp.b1.powi(t);
    let bc2 = 1.0 - hp.b2.powi(t);
    for (pi, g) in grads.iter().enumerate() {
        let (pv, mv, vv) = (&mut params[pi], &mut m[pi], &mut v[pi]);
        for j in 0..g.len() {
            let gj = g[j] * scale;
            mv[j] = hp.b1 * mv[j] + (1.0 - hp.b1) * gj;
            vv[j] = hp.b2 * vv[j] + (1.0 - hp.b2) * gj * gj;
            let mhat = mv[j] / bc1;
            let vhat = vv[j] / bc2;
            pv[j] -= lr * mhat / (vhat.sqrt() + hp.eps);
        }
    }
    gnorm
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dims() -> Dims {
        Dims { vocab: 8, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 12, max_seq: 6 }
    }

    fn views(params: &[HostTensor]) -> Vec<&[f32]> {
        params.iter().map(|t| t.as_f32().unwrap()).collect()
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let dims = tiny_dims();
        let a = init_params(&dims, 7);
        let b = init_params(&dims, 7);
        let c = init_params(&dims, 8);
        assert_eq!(a.len(), dims.n_params());
        assert_eq!(a, b);
        assert_ne!(a, c);
        // The python scheme's quirk carries over: every `ln*` parameter
        // (scales AND biases) initialises to ones; MLP biases to zeros.
        assert!(a[2].as_f32().unwrap().iter().all(|&x| x == 1.0), "ln1_scale");
        assert!(a[3].as_f32().unwrap().iter().all(|&x| x == 1.0), "ln1_bias");
        assert!(a[2 + 9].as_f32().unwrap().iter().all(|&x| x == 0.0), "b1");
    }

    #[test]
    fn forward_logits_are_finite_and_softmax_normalises() {
        let dims = tiny_dims();
        let params = init_params(&dims, 1);
        let p = views(&params);
        let (b, s) = (2, 5);
        let tokens: Vec<i32> = (0..b * s).map(|i| (i % dims.vocab) as i32).collect();
        let cache = forward(&dims, &p, &tokens, b, s);
        assert!(cache.logits.iter().all(|x| x.is_finite()));
        let stats = sequence_logp(&dims, &cache, &tokens);
        for ti in 0..b * (s - 1) {
            let prow = &stats.probs[ti * dims.vocab..(ti + 1) * dims.vocab];
            let total: f32 = prow.iter().sum();
            assert!((total - 1.0).abs() < 1e-4, "sum p = {total}");
            assert!(stats.logp[ti] <= 1e-5);
            assert!(stats.entropy[ti] > 0.0);
        }
    }

    /// The load-bearing test: analytic parameter gradients of a masked
    /// log-prob objective vs central finite differences.
    #[test]
    fn gradients_match_finite_differences() {
        let dims = tiny_dims();
        let params = init_params(&dims, 3);
        let (b, s) = (2, 4);
        let t = s - 1;
        let tokens: Vec<i32> = vec![1, 2, 3, 4, 5, 6, 7, 0];
        let mask: Vec<f32> = vec![1.0, 1.0, 0.0, 0.0, 1.0, 1.0];
        assert_eq!(mask.len(), b * t);

        let loss = |ps: &[HostTensor]| -> f32 {
            let p = views(ps);
            let cache = forward(&dims, &p, &tokens, b, s);
            let stats = sequence_logp(&dims, &cache, &tokens);
            stats.logp.iter().zip(&mask).map(|(lp, mk)| lp * mk).sum()
        };

        // Analytic gradients of sum(mask * logp).
        let p = views(&params);
        let cache = forward(&dims, &p, &tokens, b, s);
        let stats = sequence_logp(&dims, &cache, &tokens);
        let dlogits = dlogits_from_dlogp(&dims, &cache, &stats, &tokens, &mask);
        let grads = backward(&dims, &p, &cache, &tokens, &dlogits);

        let eps = 1e-2f32;
        let specs = dims.param_specs();
        for (pi, spec) in specs.iter().enumerate() {
            let n = spec.elements();
            // Sample a few entries per tensor.
            for &j in [0usize, n / 2, n - 1].iter() {
                let mut plus = params.clone();
                let mut minus = params.clone();
                if let HostTensor::F32 { data, .. } = &mut plus[pi] {
                    data[j] += eps;
                }
                if let HostTensor::F32 { data, .. } = &mut minus[pi] {
                    data[j] -= eps;
                }
                let num = (loss(&plus) - loss(&minus)) / (2.0 * eps);
                let ana = grads[pi][j];
                assert!(
                    (num - ana).abs() <= 5e-3 + 0.05 * num.abs().max(ana.abs()),
                    "param {} [{j}]: numeric {num} vs analytic {ana}",
                    spec.name
                );
            }
        }
    }

    #[test]
    fn adam_moves_params_against_gradient_and_clips() {
        let hp = AdamHp { b1: 0.9, b2: 0.95, eps: 1e-8, grad_clip: 1.0 };
        let mut params = vec![vec![0.0f32; 2]];
        let mut m = vec![vec![0.0f32; 2]];
        let mut v = vec![vec![0.0f32; 2]];
        let grads = vec![vec![3.0f32, 4.0]]; // norm 5 -> clipped to 1
        let gnorm = adam_update(&hp, 0.1, &mut params, &mut m, &mut v, &grads, 0);
        assert!((gnorm - 5.0).abs() < 1e-5);
        assert!(params[0][0] < 0.0 && params[0][1] < 0.0, "{params:?}");
        // Bias-corrected first step ~= -lr * sign(g).
        assert!((params[0][0] + 0.1).abs() < 1e-3, "{}", params[0][0]);
    }
}
