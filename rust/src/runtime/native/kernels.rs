//! Shared dense-math kernels for the native backend: cache-blocked,
//! register-tiled GEMM microkernels plus a small `std::thread` worker pool
//! with low-overhead chunk dispatch.
//!
//! Every kernel here is used by *both* halves of the system: the
//! incremental decode sessions (`super::kv`) and the train/prox
//! forward-backward paths (`super::model`).
//!
//! # GEMM blocking scheme
//!
//! The matmul family packs `b` into contiguous [`NR`]-wide column panels
//! (zero-padded at a ragged right edge), splits `k` into [`KC`]-sized
//! blocks, and computes [`MR`]`x`[`NR`] output tiles in a fixed-size
//! register accumulator. There is no `NC` blocking: each `k` block sweeps
//! all column panels (the widest operand here, `d_ff`/`vocab`, fits
//! comfortably in L2 once packed). The q/k/v projection triple runs through
//! fused multi-`B` entry points ([`matmul_set_multi`],
//! [`matmul_at_b_acc_multi`], [`matmul_set_packed_multi`]) that pack each
//! shared `A` micropanel once and stream it through all three weight
//! panels.
//!
//! # Register tiles and runtime ISA dispatch
//!
//! The `MR x NR` tile has two interchangeable implementations: a portable
//! scalar tile with branch-free loops the compiler autovectorizes, and an
//! explicit AVX2 tile (`std::arch`, 8 f32 lanes = the [`NR`] panel columns)
//! selected once per process when `is_x86_feature_detected!` approves.
//! `A3PO_KERNEL=scalar|simd` overrides the choice, and
//! [`set_kernel_override`] does the same in-process (benches use it for
//! side-by-side timing). The AVX2 tile deliberately uses separate multiply
//! and add instructions rather than `vfmadd`: a fused multiply-add would
//! skip the intermediate rounding the scalar tile performs and break
//! scalar-vs-SIMD bit-equality — the speedup comes from lane width, not
//! from fewer roundings.
//!
//! # Determinism contract
//!
//! Every output element accumulates in an order that is a pure function of
//! the blocking — within each `KC` block, strictly ascending `p`, into a
//! private register sum that is then added to `c` block by block — and
//! *never* a function of the thread count, the chunk partition, the row
//! tile an element lands in, or the selected register tile (padding lanes
//! multiply into separate lanes and are discarded; the SIMD tile replays
//! the scalar tile's per-lane operation sequence exactly). The scalar
//! small-operand path replays the identical per-element operation sequence,
//! and the multi-`B` path reuses only the `A` pack — each output's
//! accumulation order is untouched. Threaded, serial, packed, unpacked,
//! scalar, SIMD, fused-multi-`B`, and any-`A3PO_THREADS` runs are therefore
//! bit-identical; the decode/train parity suites and
//! `tests/kernel_parity.rs` pin this.
//!
//! # Dispatch
//!
//! A run is a shared atomic chunk counter over pre-partitioned row ranges:
//! workers (and the calling thread — it runs chunks instead of idling on
//! the completion latch) claim chunk indices with one `fetch_add` each, so
//! there is no per-job heap allocation and no channel. The legacy
//! `Vec<Box<dyn FnOnce>>` batch API ([`WorkerPool::run`]) remains for
//! irregular job shapes, now feeding the same shared queue: jobs are
//! enqueued under one short-lived lock and workers block on a condvar (not
//! on a channel-receiver mutex), so dequeues never serialise.
//!
//! Pool sizing: `A3PO_THREADS` overrides; the default is
//! `available_parallelism` capped at [`MAX_THREADS`]. Kernels fall back to
//! the serial path for small operands (below [`PAR_MIN_WORK`] multiply-adds)
//! where fan-out overhead would dominate, or when
//! [`set_force_serial`]`(true)` is active (benches use this to measure the
//! threading speedup in-process).

#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Hard cap on pool size (beyond this, the tiny matmuls here stop scaling).
pub const MAX_THREADS: usize = 16;

/// Minimum multiply-add count before a kernel fans out to the pool.
const PAR_MIN_WORK: usize = 1 << 17;

static FORCE_SERIAL: AtomicBool = AtomicBool::new(false);

/// Force every kernel onto the serial path (process-global). Results are
/// identical either way; benches toggle this to isolate the thread-pool
/// contribution to throughput.
pub fn set_force_serial(v: bool) {
    FORCE_SERIAL.store(v, Ordering::SeqCst);
}

pub fn force_serial() -> bool {
    FORCE_SERIAL.load(Ordering::SeqCst)
}

// ---------------------------------------------------------------------------
// Worker pool

type Job = Box<dyn FnOnce() + Send + 'static>;

/// One counter-claimed chunked run (see [`run_chunks`]). Workers claim chunk
/// indices with a single `fetch_add`; no allocation happens per chunk.
struct RunTask {
    next: AtomicUsize,
    n_chunks: usize,
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
    /// The chunk body with its borrow lifetime erased. Only dereferenced
    /// for claimed indices `< n_chunks`, all of which complete before
    /// [`run_chunks`] returns — so every call happens while the original
    /// closure is alive.
    func: *const (dyn Fn(usize) + Sync),
}

// SAFETY: `func` is only called between enqueue and latch-release inside
// `run_chunks`, while the pointee is borrowed by the blocked caller; all
// other fields are Sync synchronisation primitives.
unsafe impl Send for RunTask {}
unsafe impl Sync for RunTask {}

impl RunTask {
    fn is_exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.n_chunks
    }

    /// Claim and run chunks until none remain. Called by workers *and* by
    /// the submitting thread.
    fn work(&self) {
        loop {
            let idx = self.next.fetch_add(1, Ordering::Relaxed);
            if idx >= self.n_chunks {
                return;
            }
            // SAFETY: see the `func` field invariant above.
            let func = unsafe { &*self.func };
            if catch_unwind(AssertUnwindSafe(|| func(idx))).is_err() {
                self.panicked.store(true, Ordering::SeqCst);
            }
            let mut g = self.remaining.lock().unwrap();
            *g -= 1;
            if *g == 0 {
                self.cv.notify_all();
            }
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Queue entries: boxed one-shot jobs (the legacy batch API) or shared
/// chunk-claiming tasks.
enum Work {
    Job(Job),
    Task(Arc<RunTask>),
}

struct QueueState {
    items: VecDeque<Work>,
    shutdown: bool,
}

struct Shared {
    q: Mutex<QueueState>,
    cv: Condvar,
}

enum WorkItem {
    Job(Job),
    Task(Arc<RunTask>),
}

fn worker_loop(shared: Arc<Shared>) {
    enum Take {
        PopExhausted,
        Task(Arc<RunTask>),
        Job,
        Empty,
    }
    loop {
        let item = {
            let mut g = shared.q.lock().unwrap();
            loop {
                let take = match g.items.front() {
                    Some(Work::Task(t)) => {
                        if t.is_exhausted() {
                            Take::PopExhausted
                        } else {
                            // Leave the task at the front so every idle
                            // worker keeps helping until it is exhausted.
                            Take::Task(t.clone())
                        }
                    }
                    Some(Work::Job(_)) => Take::Job,
                    None => Take::Empty,
                };
                match take {
                    Take::PopExhausted => {
                        g.items.pop_front();
                    }
                    Take::Task(t) => break Some(WorkItem::Task(t)),
                    Take::Job => {
                        if let Some(Work::Job(job)) = g.items.pop_front() {
                            break Some(WorkItem::Job(job));
                        }
                    }
                    Take::Empty => {
                        if g.shutdown {
                            break None;
                        }
                        g = shared.cv.wait(g).unwrap();
                    }
                }
            }
        };
        match item {
            Some(WorkItem::Task(t)) => t.work(),
            Some(WorkItem::Job(job)) => job(),
            None => return,
        }
    }
}

/// Completion is signalled from a `Drop` guard so a panicking job still
/// releases the caller instead of deadlocking `Latch::wait`.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn complete_one(&self) {
        let mut g = self.remaining.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

struct DoneGuard {
    latch: Arc<Latch>,
}

impl Drop for DoneGuard {
    fn drop(&mut self) {
        self.latch.complete_one();
    }
}

/// A fixed set of persistent worker threads over one shared work queue.
pub struct WorkerPool {
    workers: usize,
    shared: Option<Arc<Shared>>,
}

impl WorkerPool {
    fn new(workers: usize) -> WorkerPool {
        if workers <= 1 {
            return WorkerPool { workers: 1, shared: None };
        }
        let shared = Arc::new(Shared {
            q: Mutex::new(QueueState { items: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        for i in 0..workers {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name(format!("a3po-kernel-{i}"))
                .spawn(move || worker_loop(shared))
                .expect("spawning kernel worker");
        }
        WorkerPool { workers, shared: Some(shared) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    fn push_task(&self, task: Arc<RunTask>) {
        let shared = self.shared.as_ref().expect("push_task on a serial pool");
        {
            let mut g = shared.q.lock().unwrap();
            g.items.push_back(Work::Task(task));
        }
        shared.cv.notify_all();
    }

    /// Drop a finished task that no worker happened to pop yet.
    fn remove_task(&self, task: &Arc<RunTask>) {
        let shared = self.shared.as_ref().expect("remove_task on a serial pool");
        let mut g = shared.q.lock().unwrap();
        g.items.retain(|w| !matches!(w, Work::Task(t) if Arc::ptr_eq(t, task)));
    }

    /// Run a batch of jobs, blocking until every one has finished. Jobs may
    /// borrow from the caller's stack: the blocking wait is what makes the
    /// internal lifetime erasure sound. Panics if any job panicked.
    ///
    /// Jobs are appended to the shared queue under one short-lived lock and
    /// picked up by condvar-blocked workers, so N jobs are in flight
    /// concurrently as soon as N workers wake (the old channel path sent
    /// while holding a sender mutex and workers blocked in `recv` holding
    /// the receiver mutex, serialising every hand-off).
    pub fn run<'a>(&self, mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        match jobs.len() {
            0 => return,
            1 => {
                (jobs.pop().unwrap())();
                return;
            }
            _ => {}
        }
        let shared = match &self.shared {
            Some(shared) if !force_serial() => shared,
            _ => {
                for job in jobs {
                    job();
                }
                return;
            }
        };
        let latch = Arc::new(Latch {
            remaining: Mutex::new(jobs.len()),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut g = shared.q.lock().unwrap();
            for job in jobs {
                // SAFETY: `run` blocks on the latch until every submitted
                // job has completed (the Drop guard fires even on panic), so
                // all borrows captured in `job` strictly outlive its
                // execution. Only the lifetime is erased; the layout of the
                // boxed trait object is unchanged.
                let job: Job = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'a>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(job)
                };
                let latch = latch.clone();
                g.items.push_back(Work::Job(Box::new(move || {
                    let guard = DoneGuard { latch };
                    if catch_unwind(AssertUnwindSafe(job)).is_err() {
                        guard.latch.panicked.store(true, Ordering::SeqCst);
                    }
                    drop(guard);
                })));
            }
        }
        shared.cv.notify_all();
        latch.wait();
        if latch.panicked.load(Ordering::SeqCst) {
            panic!("a kernel worker job panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            {
                let mut g = shared.q.lock().unwrap();
                g.shutdown = true;
            }
            shared.cv.notify_all();
        }
    }
}

/// The process-global kernel pool (created on first use).
pub fn pool() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(configured_threads()))
}

/// The pool size this process uses, computed *without* constructing the
/// pool — logging and bench-metadata callers must not spawn the worker
/// threads as a side effect of asking.
pub fn configured_threads() -> usize {
    std::env::var("A3PO_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
        .clamp(1, MAX_THREADS)
}

/// Run `f(0..n_chunks)` with chunks claimed off a shared atomic counter by
/// the pool workers *and* the calling thread. Chunk bodies must write only
/// disjoint state (the kernels slice disjoint output rows). Blocks until
/// every chunk has run; panics if any chunk panicked. Results must not
/// depend on which thread runs which chunk — the kernels guarantee this by
/// making accumulation order a pure function of the blocking.
pub fn run_chunks(n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
    if n_chunks == 0 {
        return;
    }
    // `force_serial()` before `pool()`: serial benches and one-shot tests
    // must not spawn the worker threads as a side effect of the check.
    if n_chunks == 1 || force_serial() || pool().workers() <= 1 {
        for i in 0..n_chunks {
            f(i);
        }
        return;
    }
    // SAFETY: the borrow of `f` is erased, but `run_chunks` blocks on the
    // latch until every claimed chunk has finished, and workers never call
    // the closure for indices >= n_chunks — so no call outlives `f`.
    let func = unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
    };
    let task = Arc::new(RunTask {
        next: AtomicUsize::new(0),
        n_chunks,
        remaining: Mutex::new(n_chunks),
        cv: Condvar::new(),
        panicked: AtomicBool::new(false),
        func,
    });
    pool().push_task(task.clone());
    // The caller claims chunks too instead of idling on the latch.
    task.work();
    task.wait();
    pool().remove_task(&task);
    if task.panicked.load(Ordering::SeqCst) {
        panic!("a kernel worker job panicked");
    }
}

/// Should an op of `work` multiply-adds with `rows` splittable rows fan out?
fn parallel_ok(rows: usize, work: usize) -> bool {
    // `force_serial()` before `pool()` so forced-serial callers never spawn
    // the worker threads as a side effect of asking.
    rows >= 2 && work >= PAR_MIN_WORK && !force_serial() && pool().workers() >= 2
}

/// Raw mutable base pointer, `Send + Sync` so disjoint row ranges of one
/// output buffer can be sliced per-chunk inside a `Fn(usize)` closure.
/// Soundness: every user derives non-overlapping slices from it.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);

unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

// ---------------------------------------------------------------------------
// Blocked GEMM microkernels

/// Register-tile rows: the microkernel accumulates an `MR x NR` output tile.
pub const MR: usize = 4;
/// Register-tile columns (8 f32 lanes — two SSE registers or one AVX).
pub const NR: usize = 8;
/// k-dimension cache block: one packed `B` panel column (`KC·NR` floats)
/// plus the `A` micropanel (`MR·KC` floats, on the stack) stay L1-resident.
pub const KC: usize = 256;

/// Below this many multiply-adds the pack pass costs more than blocking
/// saves; a scalar path that replays the identical per-element operation
/// order runs instead (results are bit-identical either way).
const SMALL_GEMM_WORK: usize = 1 << 13;

#[allow(clippy::manual_div_ceil)] // usize::div_ceil needs rustc >= 1.73
fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

// ---------------------------------------------------------------------------
// Register-tile ISA selection (runtime dispatch)

/// Which implementation of the `MR x NR` register tile executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelIsa {
    /// Portable scalar tile (autovectorized by the compiler).
    Scalar,
    /// Explicit `std::arch` AVX2 tile (x86-64, runtime-detected).
    Avx2,
}

impl KernelIsa {
    pub fn name(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Avx2 => "avx2",
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn simd_available_impl() -> bool {
    std::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn simd_available_impl() -> bool {
    false
}

/// Can this host execute the SIMD register tile? (`std` caches detection.)
pub fn simd_available() -> bool {
    simd_available_impl()
}

/// In-process override: 0 = follow `A3PO_KERNEL` / detection, 1 = scalar,
/// 2 = SIMD-if-available.
static KERNEL_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force a register tile in-process (process-global), mirroring
/// [`set_force_serial`]: benches and parity tests toggle it to compare the
/// scalar and SIMD tiles without re-execing. Results are bit-identical
/// either way. `Some(Avx2)` on a host without AVX2 falls back to scalar.
pub fn set_kernel_override(isa: Option<KernelIsa>) {
    let v = match isa {
        None => 0,
        Some(KernelIsa::Scalar) => 1,
        Some(KernelIsa::Avx2) => 2,
    };
    KERNEL_OVERRIDE.store(v, Ordering::SeqCst);
}

/// The `(isa, forced_by_env)` choice from `A3PO_KERNEL` / detection, read
/// once per process (like `A3PO_THREADS`: per-process pinning is what makes
/// the cross-process parity checks meaningful).
fn env_choice() -> (KernelIsa, bool) {
    static CHOICE: OnceLock<(KernelIsa, bool)> = OnceLock::new();
    *CHOICE.get_or_init(|| {
        let detected = if simd_available() { KernelIsa::Avx2 } else { KernelIsa::Scalar };
        match std::env::var("A3PO_KERNEL").ok().as_deref() {
            Some("scalar") => (KernelIsa::Scalar, true),
            Some("simd") => {
                if simd_available() {
                    (KernelIsa::Avx2, true)
                } else {
                    eprintln!("a3po: A3PO_KERNEL=simd but this host lacks AVX2; using scalar");
                    (KernelIsa::Scalar, true)
                }
            }
            Some(other) => {
                eprintln!(
                    "a3po: unrecognised A3PO_KERNEL={other:?} (expected scalar|simd); \
                     auto-detecting"
                );
                (detected, false)
            }
            None => (detected, false),
        }
    })
}

/// The register tile the next GEMM will run: in-process override first,
/// then `A3PO_KERNEL`, then feature detection.
pub fn active_isa() -> KernelIsa {
    match KERNEL_OVERRIDE.load(Ordering::SeqCst) {
        1 => KernelIsa::Scalar,
        2 if simd_available() => KernelIsa::Avx2,
        2 => KernelIsa::Scalar,
        _ => env_choice().0,
    }
}

/// Snapshot of the selected kernel path, for startup logging and bench
/// artifact metadata.
#[derive(Clone, Debug)]
pub struct KernelInfo {
    pub isa: KernelIsa,
    pub simd_available: bool,
    /// True when `A3PO_KERNEL` (not auto-detection) picked the tile.
    pub forced_by_env: bool,
    pub mr: usize,
    pub nr: usize,
    pub kc: usize,
    pub threads: usize,
}

pub fn kernel_info() -> KernelInfo {
    let (_, forced_by_env) = env_choice();
    KernelInfo {
        isa: active_isa(),
        simd_available: simd_available(),
        forced_by_env,
        mr: MR,
        nr: NR,
        kc: KC,
        threads: configured_threads(),
    }
}

/// Log the selected kernel path once per process (stderr; `A3PO_QUIET`
/// suppresses it). Called at native backend construction so every train or
/// decode run states which code path produced its numbers.
pub fn log_kernel_path_once() {
    static LOGGED: AtomicBool = AtomicBool::new(false);
    if LOGGED.swap(true, Ordering::SeqCst) || std::env::var_os("A3PO_QUIET").is_some() {
        return;
    }
    let info = kernel_info();
    let how = if info.forced_by_env {
        "A3PO_KERNEL"
    } else if info.simd_available {
        "detected"
    } else {
        "no simd on this host"
    };
    eprintln!(
        "a3po kernels: isa={} ({how}), tile {}x{}x{} (MRxNRxKC), {} threads",
        info.isa.name(),
        info.mr,
        info.nr,
        info.kc,
        info.threads
    );
}

/// How the `a` operand is laid out.
#[derive(Clone, Copy)]
enum AMode {
    /// `a` is `[m, k]` row-major: element `(i, p)` at `a[i*k + p]`.
    Rows,
    /// `a` is `[k, m]` (the `aᵀ·b` gradient variant): `(i, p)` at `a[p*m + i]`.
    Cols,
}

/// Reusable per-thread pack scratch: one buffer per caller thread, grown
/// once and reused across layers, steps, and sessions.
thread_local! {
    static PACK_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Pack `b` into `[k-block][column-panel][p][lane]` order: for each `KC`
/// block, `NR`-wide column panels stored contiguously with ascending `p`
/// inside, zero-padded at a ragged right edge. `bt = true` reads `b` as the
/// `[n, k]` transposed operand of the `a·bᵀ` variant.
fn pack_b_into(dst: &mut Vec<f32>, b: &[f32], k: usize, n: usize, bt: bool) {
    let n_panels = div_ceil(n, NR);
    dst.clear();
    dst.resize(k * n_panels * NR, 0.0);
    pack_b_panels(dst, b, k, n, bt);
}

/// Pack into a pre-zeroed `k * div_ceil(n, NR) * NR` slice (see
/// [`pack_b_into`] for the layout). Ragged-edge padding lanes are *left*
/// untouched, so the caller must hand in zeroed memory — this is what lets
/// the multi-`B` path pack several operands back-to-back in one scratch
/// buffer.
fn pack_b_panels(dst: &mut [f32], b: &[f32], k: usize, n: usize, bt: bool) {
    let n_panels = div_ceil(n, NR);
    let kblocks = div_ceil(k, KC);
    for kb in 0..kblocks {
        let p0 = kb * KC;
        let kcl = KC.min(k - p0);
        let base = kb * KC * n_panels * NR;
        for jp in 0..n_panels {
            let j0 = jp * NR;
            let jn = NR.min(n - j0);
            let panel = &mut dst[base + jp * kcl * NR..base + (jp + 1) * kcl * NR];
            for p in 0..kcl {
                let row = &mut panel[p * NR..(p + 1) * NR];
                if bt {
                    for r in 0..jn {
                        row[r] = b[(j0 + r) * k + (p0 + p)];
                    }
                } else {
                    row[..jn].copy_from_slice(&b[(p0 + p) * n + j0..(p0 + p) * n + j0 + jn]);
                }
                // row[jn..] stays zero: padding lanes accumulate garbage-free
                // into discarded lanes and never touch real output.
            }
        }
    }
}

/// The portable scalar `MR x NR` register tile: branch-free fixed-trip
/// loops the compiler autovectorizes. Each `p` step does one rounded
/// multiply then one rounded add per output lane; the AVX2 tile replays
/// exactly this per-lane operation sequence, so the two are bit-identical.
#[inline(always)]
fn tile_scalar(
    acc: &mut [[f32; NR]; MR],
    apack: &[f32; MR * KC],
    panel: &[f32],
    kcl: usize,
    mr: usize,
) {
    for p in 0..kcl {
        let brow = &panel[p * NR..(p + 1) * NR];
        for r in 0..mr {
            let av = apack[r * KC + p];
            let arow = &mut acc[r];
            for j in 0..NR {
                arow[j] += av * brow[j];
            }
        }
    }
}

/// Explicit AVX2 register tile (selected at runtime; never reached on other
/// architectures).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{KC, MR, NR};
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_storeu_ps,
    };

    // The lane layout below hardcodes the tile geometry.
    const _: () = assert!(MR == 4 && NR == 8, "the AVX2 tile is written for a 4x8 f32 tile");

    /// AVX2 `MR x NR` tile: lane `j` of each 256-bit accumulator is panel
    /// column `j`, and each `p` step performs one rounded multiply
    /// (`vmulps`) then one rounded add (`vaddps`) per lane — deliberately
    /// *not* `vfmadd`: fusing would skip the intermediate rounding the
    /// scalar tile performs and break the scalar ≡ SIMD bit-equality
    /// contract. The win is eight lanes per instruction, not fewer
    /// roundings.
    ///
    /// All `MR` rows are computed unconditionally — on a ragged last row
    /// block the caller zero-fills `apack` rows `mr..MR`, so the extra rows
    /// accumulate zeros into registers whose write-back the caller skips.
    ///
    /// # Safety
    ///
    /// AVX2 must be available (`is_x86_feature_detected!("avx2")`), `panel`
    /// must hold at least `kcl * NR` floats, and `kcl <= KC`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn tile(
        acc: &mut [[f32; NR]; MR],
        apack: &[f32; MR * KC],
        panel: &[f32],
        kcl: usize,
    ) {
        debug_assert!(kcl <= KC);
        debug_assert!(panel.len() >= kcl * NR);
        let mut v0 = _mm256_loadu_ps(acc[0].as_ptr());
        let mut v1 = _mm256_loadu_ps(acc[1].as_ptr());
        let mut v2 = _mm256_loadu_ps(acc[2].as_ptr());
        let mut v3 = _mm256_loadu_ps(acc[3].as_ptr());
        let pp = panel.as_ptr();
        let ap = apack.as_ptr();
        for p in 0..kcl {
            let bv = _mm256_loadu_ps(pp.add(p * NR));
            v0 = _mm256_add_ps(v0, _mm256_mul_ps(_mm256_set1_ps(*ap.add(p)), bv));
            v1 = _mm256_add_ps(v1, _mm256_mul_ps(_mm256_set1_ps(*ap.add(KC + p)), bv));
            v2 = _mm256_add_ps(v2, _mm256_mul_ps(_mm256_set1_ps(*ap.add(2 * KC + p)), bv));
            v3 = _mm256_add_ps(v3, _mm256_mul_ps(_mm256_set1_ps(*ap.add(3 * KC + p)), bv));
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), v0);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), v1);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), v2);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), v3);
    }
}

/// Run the selected register tile for one panel:
/// `acc[r][j] += sum_p apack[r*KC + p] * panel[p*NR + j]`.
#[inline(always)]
fn run_tile(
    acc: &mut [[f32; NR]; MR],
    apack: &[f32; MR * KC],
    panel: &[f32],
    kcl: usize,
    mr: usize,
    isa: KernelIsa,
) {
    match isa {
        // SAFETY: `Avx2` is only selected after feature detection succeeded
        // (see `active_isa`), and the callers zero-fill `apack` rows
        // `mr..MR` so the full-height tile reads no stale values.
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => unsafe { avx2::tile(acc, apack, panel, kcl) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => tile_scalar(acc, apack, panel, kcl, mr),
        KernelIsa::Scalar => tile_scalar(acc, apack, panel, kcl, mr),
    }
}

/// The blocked compute over output rows `i0..i0 + rows` (`c` holds exactly
/// those rows). `set` overwrites `c` on the first `k` block instead of
/// accumulating; `fused` applies `pre += bias; act = gelu(pre)` once each
/// row's accumulation is complete.
fn gemm_rows(
    c: &mut [f32],
    a: &[f32],
    amode: AMode,
    packed: &[f32],
    i0: usize,
    rows: usize,
    m: usize,
    k: usize,
    n: usize,
    set: bool,
    isa: KernelIsa,
    mut fused: Option<(&mut [f32], &[f32])>,
) {
    let n_panels = div_ceil(n, NR);
    let kblocks = div_ceil(k, KC);
    let mut apack = [0.0f32; MR * KC];
    let mut ib = 0;
    while ib < rows {
        let mr = MR.min(rows - ib);
        for kb in 0..kblocks {
            let p0 = kb * KC;
            let kcl = KC.min(k - p0);
            // Pack the A micropanel for this row block x k block.
            for r in 0..mr {
                let gi = i0 + ib + r;
                match amode {
                    AMode::Rows => {
                        apack[r * KC..r * KC + kcl]
                            .copy_from_slice(&a[gi * k + p0..gi * k + p0 + kcl]);
                    }
                    AMode::Cols => {
                        for p in 0..kcl {
                            apack[r * KC + p] = a[(p0 + p) * m + gi];
                        }
                    }
                }
            }
            // Rows `mr..MR` may hold a previous block's values; zero them so
            // the full-height SIMD tile multiplies zeros into its discarded
            // rows (only the final ragged row block ever pays this).
            for r in mr..MR {
                apack[r * KC..r * KC + kcl].fill(0.0);
            }
            let first = kb == 0;
            let block_base = kb * KC * n_panels * NR;
            for jp in 0..n_panels {
                let j0 = jp * NR;
                let jn = NR.min(n - j0);
                let panel = &packed[block_base + jp * kcl * NR..block_base + (jp + 1) * kcl * NR];
                let mut acc = [[0.0f32; NR]; MR];
                run_tile(&mut acc, &apack, panel, kcl, mr, isa);
                for r in 0..mr {
                    let crow = &mut c[(ib + r) * n + j0..(ib + r) * n + j0 + jn];
                    if set && first {
                        crow.copy_from_slice(&acc[r][..jn]);
                    } else {
                        for j in 0..jn {
                            crow[j] += acc[r][j];
                        }
                    }
                }
            }
        }
        if let Some((act, bias)) = fused.as_mut() {
            for r in 0..mr {
                let crow = &mut c[(ib + r) * n..(ib + r) * n + n];
                let arow = &mut act[(ib + r) * n..(ib + r) * n + n];
                for j in 0..n {
                    let v = crow[j] + bias[j];
                    crow[j] = v;
                    arow[j] = gelu(v);
                }
            }
        }
        ib += MR;
    }
}

/// Scalar path for operands too small to amortise packing. Replays the
/// blocked path's exact per-element operation sequence (same `KC` blocks,
/// same ascending-`p` register sums, same write-back), so results are
/// bit-identical to [`gemm_rows`] — path choice can never change output.
fn gemm_small(
    c: &mut [f32],
    a: &[f32],
    amode: AMode,
    b: &[f32],
    bt: bool,
    m: usize,
    k: usize,
    n: usize,
    set: bool,
    mut fused: Option<(&mut [f32], &[f32])>,
) {
    let kblocks = div_ceil(k, KC);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            for kb in 0..kblocks {
                let p0 = kb * KC;
                let kcl = KC.min(k - p0);
                let mut acc = 0.0f32;
                for p in 0..kcl {
                    let av = match amode {
                        AMode::Rows => a[i * k + p0 + p],
                        AMode::Cols => a[(p0 + p) * m + i],
                    };
                    let bv = if bt { b[j * k + p0 + p] } else { b[(p0 + p) * n + j] };
                    acc += av * bv;
                }
                if set && kb == 0 {
                    crow[j] = acc;
                } else {
                    crow[j] += acc;
                }
            }
        }
        if let Some((act, bias)) = fused.as_mut() {
            for j in 0..n {
                let v = crow[j] + bias[j];
                crow[j] = v;
                act[i * n + j] = gelu(v);
            }
        }
    }
}

/// Blocked GEMM over a pre-packed `b`, row-parallel when worthwhile.
fn gemm_packed(
    c: &mut [f32],
    a: &[f32],
    amode: AMode,
    packed: &[f32],
    m: usize,
    k: usize,
    n: usize,
    set: bool,
    fused: Option<(&mut [f32], &[f32])>,
) {
    let isa = active_isa();
    let blocks = div_ceil(m, MR);
    if blocks < 2 || !parallel_ok(m, m * k * n) {
        gemm_rows(c, a, amode, packed, 0, m, m, k, n, set, isa, fused);
        return;
    }
    // Chunk in whole MR-row blocks, a few chunks per worker so the atomic
    // claim loop load-balances ragged finish times.
    let bpc = div_ceil(blocks, pool().workers() * 4).max(1);
    let n_chunks = div_ceil(blocks, bpc);
    if n_chunks < 2 {
        gemm_rows(c, a, amode, packed, 0, m, m, k, n, set, isa, fused);
        return;
    }
    let cptr = SendPtr(c.as_mut_ptr());
    let (act_ptr, bias): (Option<SendPtr>, Option<&[f32]>) = match fused {
        Some((act, bias)) => (Some(SendPtr(act.as_mut_ptr())), Some(bias)),
        None => (None, None),
    };
    run_chunks(n_chunks, &|ci: usize| {
        let i0 = ci * bpc * MR;
        let i1 = m.min(i0 + bpc * MR);
        let rows = i1 - i0;
        // SAFETY: chunks cover disjoint row ranges of `c` (and `act`), so
        // the per-chunk mutable slices never alias.
        let cc = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
        let fc = match (act_ptr, bias) {
            (Some(ap), Some(bs)) => Some((
                unsafe { std::slice::from_raw_parts_mut(ap.0.add(i0 * n), rows * n) },
                bs,
            )),
            _ => None,
        };
        gemm_rows(cc, a, amode, packed, i0, rows, m, k, n, set, isa, fc);
    });
}

/// Entry point for unpacked operands: small ops take the scalar path, the
/// rest pack `b` into per-thread reusable scratch and run blocked.
fn gemm(
    c: &mut [f32],
    a: &[f32],
    amode: AMode,
    b: &[f32],
    bt: bool,
    m: usize,
    k: usize,
    n: usize,
    set: bool,
    fused: Option<(&mut [f32], &[f32])>,
) {
    debug_assert_eq!(c.len(), m * n);
    if m * k * n < SMALL_GEMM_WORK {
        gemm_small(c, a, amode, b, bt, m, k, n, set, fused);
        return;
    }
    PACK_SCRATCH.with(|s| {
        let mut buf = s.borrow_mut();
        pack_b_into(&mut buf, b, k, n, bt);
        gemm_packed(c, a, amode, &buf, m, k, n, set, fused);
    });
}

// ---------------------------------------------------------------------------
// Matmul family (row-major; bit-identical across thread counts and paths)

/// c[m,n] += a[m,k] · b[k,n]
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    gemm(c, a, AMode::Rows, b, false, m, k, n, false, None);
}

/// c[m,n] = a[m,k] · b[k,n] — overwrite variant: no zeroing pass over `c`
/// (callers drop one full memory sweep per projection vs reset + acc).
pub fn matmul_set(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    gemm(c, a, AMode::Rows, b, false, m, k, n, true, None);
}

/// c[m,n] = a[m,k] · b[k,n]
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_set(&mut c, a, b, m, k, n);
    c
}

/// Fused MLP up-projection epilogue: `pre[m,n] = a·b + bias` and
/// `act = gelu(pre)` written in the same pass over the output tile, so the
/// pre-activation buffer is swept once instead of three times.
pub fn matmul_set_bias_gelu(
    pre: &mut [f32],
    act: &mut [f32],
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(act.len(), m * n);
    debug_assert_eq!(bias.len(), n);
    gemm(pre, a, AMode::Rows, b, false, m, k, n, true, Some((act, bias)));
}

/// c[m,n] += aᵀ · b where a is [k,m] and b is [k,n] (weight gradients).
pub fn matmul_at_b_acc(c: &mut [f32], a: &[f32], b: &[f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    gemm(c, a, AMode::Cols, b, false, m, k, n, false, None);
}

/// c[m,n] += a · bᵀ where a is [m,k] and b is [n,k] (input gradients).
pub fn matmul_a_bt_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    gemm(c, a, AMode::Rows, b, true, m, k, n, false, None);
}

/// A `[k, n]` weight matrix pre-packed into the blocked panel layout, for
/// callers whose `b` operand is frozen across many GEMMs — decode sessions
/// pack each layer's weights once per snapshot and reuse them every token.
pub struct PackedB {
    data: Vec<f32>,
    k: usize,
    n: usize,
}

impl PackedB {
    pub fn pack(b: &[f32], k: usize, n: usize) -> PackedB {
        debug_assert_eq!(b.len(), k * n);
        let mut data = Vec::new();
        pack_b_into(&mut data, b, k, n, false);
        PackedB { data, k, n }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }
}

/// `c[m, n] = a[m, k] · b` against a pre-packed `b`: skips the pack pass,
/// same blocked arithmetic — results match [`matmul_set`] bit-for-bit.
pub fn matmul_set_packed(c: &mut [f32], a: &[f32], b: &PackedB, m: usize) {
    debug_assert_eq!(a.len(), m * b.k);
    debug_assert_eq!(c.len(), m * b.n);
    gemm_packed(c, a, AMode::Rows, &b.data, m, b.k, b.n, true, None);
}

/// [`matmul_set_bias_gelu`] against a pre-packed `b`.
pub fn matmul_set_bias_gelu_packed(
    pre: &mut [f32],
    act: &mut [f32],
    a: &[f32],
    b: &PackedB,
    bias: &[f32],
    m: usize,
) {
    debug_assert_eq!(a.len(), m * b.k);
    debug_assert_eq!(pre.len(), m * b.n);
    debug_assert_eq!(act.len(), m * b.n);
    debug_assert_eq!(bias.len(), b.n);
    gemm_packed(pre, a, AMode::Rows, &b.data, m, b.k, b.n, true, Some((act, bias)));
}

// ---------------------------------------------------------------------------
// Fused multi-B GEMM: one shared A micropanel streamed through several
// packed B operands (the q/k/v projection triple)

/// How many `B` operands the fused multi-`B` path carries (q, k, v).
pub const MULTI_B: usize = 3;

/// [`gemm_rows`] over [`MULTI_B`] outputs sharing one `a`: the A micropanel
/// is packed once per (row block x k block) and streamed through each
/// packed `b` in turn. Each output's per-element accumulation order is
/// exactly the single-`B` order, so results are bit-identical to separate
/// calls — only the (redundant) A-pack work is shared.
fn gemm_rows_multi(
    cs: &mut [&mut [f32]; MULTI_B],
    a: &[f32],
    amode: AMode,
    packs: &[&[f32]; MULTI_B],
    i0: usize,
    rows: usize,
    m: usize,
    k: usize,
    n: usize,
    set: bool,
    isa: KernelIsa,
) {
    let n_panels = div_ceil(n, NR);
    let kblocks = div_ceil(k, KC);
    let mut apack = [0.0f32; MR * KC];
    let mut ib = 0;
    while ib < rows {
        let mr = MR.min(rows - ib);
        for kb in 0..kblocks {
            let p0 = kb * KC;
            let kcl = KC.min(k - p0);
            for r in 0..mr {
                let gi = i0 + ib + r;
                match amode {
                    AMode::Rows => {
                        apack[r * KC..r * KC + kcl]
                            .copy_from_slice(&a[gi * k + p0..gi * k + p0 + kcl]);
                    }
                    AMode::Cols => {
                        for p in 0..kcl {
                            apack[r * KC + p] = a[(p0 + p) * m + gi];
                        }
                    }
                }
            }
            for r in mr..MR {
                apack[r * KC..r * KC + kcl].fill(0.0);
            }
            let first = kb == 0;
            let block_base = kb * KC * n_panels * NR;
            for (c, packed) in cs.iter_mut().zip(packs.iter()) {
                for jp in 0..n_panels {
                    let j0 = jp * NR;
                    let jn = NR.min(n - j0);
                    let panel =
                        &packed[block_base + jp * kcl * NR..block_base + (jp + 1) * kcl * NR];
                    let mut acc = [[0.0f32; NR]; MR];
                    run_tile(&mut acc, &apack, panel, kcl, mr, isa);
                    for r in 0..mr {
                        let crow = &mut c[(ib + r) * n + j0..(ib + r) * n + j0 + jn];
                        if set && first {
                            crow.copy_from_slice(&acc[r][..jn]);
                        } else {
                            for j in 0..jn {
                                crow[j] += acc[r][j];
                            }
                        }
                    }
                }
            }
        }
        ib += MR;
    }
}

/// Parallel driver for the multi-`B` path (mirrors [`gemm_packed`]).
fn gemm_packed_multi(
    cs: &mut [&mut [f32]; MULTI_B],
    a: &[f32],
    amode: AMode,
    packs: &[&[f32]; MULTI_B],
    m: usize,
    k: usize,
    n: usize,
    set: bool,
) {
    let isa = active_isa();
    let blocks = div_ceil(m, MR);
    if blocks < 2 || !parallel_ok(m, MULTI_B * m * k * n) {
        gemm_rows_multi(cs, a, amode, packs, 0, m, m, k, n, set, isa);
        return;
    }
    let bpc = div_ceil(blocks, pool().workers() * 4).max(1);
    let n_chunks = div_ceil(blocks, bpc);
    if n_chunks < 2 {
        gemm_rows_multi(cs, a, amode, packs, 0, m, m, k, n, set, isa);
        return;
    }
    let p0 = SendPtr(cs[0].as_mut_ptr());
    let p1 = SendPtr(cs[1].as_mut_ptr());
    let p2 = SendPtr(cs[2].as_mut_ptr());
    let ptrs = [p0, p1, p2];
    run_chunks(n_chunks, &|ci: usize| {
        let i0 = ci * bpc * MR;
        let i1 = m.min(i0 + bpc * MR);
        let rows = i1 - i0;
        // SAFETY: chunks cover disjoint row ranges of each output buffer,
        // so the per-chunk mutable slices never alias.
        let mut chunk: [&mut [f32]; MULTI_B] = [
            unsafe { std::slice::from_raw_parts_mut(ptrs[0].0.add(i0 * n), rows * n) },
            unsafe { std::slice::from_raw_parts_mut(ptrs[1].0.add(i0 * n), rows * n) },
            unsafe { std::slice::from_raw_parts_mut(ptrs[2].0.add(i0 * n), rows * n) },
        ];
        gemm_rows_multi(&mut chunk, a, amode, packs, i0, rows, m, k, n, set, isa);
    });
}

/// Entry for unpacked multi-`B` operands: small ops replay the scalar path
/// per output (bit-identical to single calls by construction); larger ops
/// pack all three `b` operands back-to-back into the per-thread scratch and
/// run the fused blocked path.
fn gemm_multi(
    cs: &mut [&mut [f32]; MULTI_B],
    a: &[f32],
    amode: AMode,
    bs: &[&[f32]; MULTI_B],
    bt: bool,
    m: usize,
    k: usize,
    n: usize,
    set: bool,
) {
    if m * k * n < SMALL_GEMM_WORK {
        for (c, b) in cs.iter_mut().zip(bs.iter()) {
            gemm_small(c, a, amode, b, bt, m, k, n, set, None);
        }
        return;
    }
    PACK_SCRATCH.with(|s| {
        let mut buf = s.borrow_mut();
        let section = k * div_ceil(n, NR) * NR;
        buf.clear();
        buf.resize(MULTI_B * section, 0.0);
        let (s0, rest) = buf.split_at_mut(section);
        let (s1, s2) = rest.split_at_mut(section);
        pack_b_panels(s0, bs[0], k, n, bt);
        pack_b_panels(s1, bs[1], k, n, bt);
        pack_b_panels(s2, bs[2], k, n, bt);
        let packs: [&[f32]; MULTI_B] = [&*s0, &*s1, &*s2];
        gemm_packed_multi(cs, a, amode, &packs, m, k, n, set);
    });
}

/// Fused q/k/v projection: `c_i = a · b_i` for [`MULTI_B`] same-shape `b`
/// operands sharing one `a` `[m, k]`. The A micropanel is packed once per
/// (row block x k block) and streamed through all three packed `b` panels,
/// cutting A-pack traffic to a third; results are bit-identical to three
/// separate [`matmul_set`] calls.
pub fn matmul_set_multi(
    mut cs: [&mut [f32]; MULTI_B],
    a: &[f32],
    bs: [&[f32]; MULTI_B],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    for (c, b) in cs.iter().zip(bs.iter()) {
        debug_assert_eq!(c.len(), m * n);
        debug_assert_eq!(b.len(), k * n);
    }
    gemm_multi(&mut cs, a, AMode::Rows, &bs, false, m, k, n, true);
}

/// `c_i += aᵀ · b_i` (`a` is `[k, m]`, each `b_i` `[k, n]`): the backward
/// counterpart of [`matmul_set_multi`] for the wq/wk/wv weight gradients.
/// Sharing matters most here — the transposed A-pack is a strided gather
/// (`a[p * m + i]`), the most expensive pack in the backward pass.
pub fn matmul_at_b_acc_multi(
    mut cs: [&mut [f32]; MULTI_B],
    a: &[f32],
    bs: [&[f32]; MULTI_B],
    k: usize,
    m: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), k * m);
    for (c, b) in cs.iter().zip(bs.iter()) {
        debug_assert_eq!(c.len(), m * n);
        debug_assert_eq!(b.len(), k * n);
    }
    gemm_multi(&mut cs, a, AMode::Cols, &bs, false, m, k, n, false);
}

/// [`matmul_set_multi`] against pre-packed weights (decode sessions hold
/// `PackedB` q/k/v panels). Like [`matmul_set_packed`], always runs the
/// blocked path — still bit-identical to the unpacked entry.
pub fn matmul_set_packed_multi(
    mut cs: [&mut [f32]; MULTI_B],
    a: &[f32],
    bs: [&PackedB; MULTI_B],
    m: usize,
) {
    let (k, n) = (bs[0].k, bs[0].n);
    debug_assert!(bs.iter().all(|b| b.k == k && b.n == n), "multi-B operands must share shape");
    debug_assert_eq!(a.len(), m * k);
    for c in cs.iter() {
        debug_assert_eq!(c.len(), m * n);
    }
    let packs: [&[f32]; MULTI_B] = [&bs[0].data, &bs[1].data, &bs[2].data];
    gemm_packed_multi(&mut cs, a, AMode::Rows, &packs, m, k, n, true);
}

// ---------------------------------------------------------------------------
// GELU (tanh approximation — jax.nn.gelu's default) and LayerNorm

const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi)
const GELU_K: f32 = 0.044_715;

pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + GELU_K * x * x * x)).tanh())
}

pub fn gelu_grad(x: f32) -> f32 {
    let u = GELU_C * (x + GELU_K * x * x * x);
    let th = u.tanh();
    let sech2 = 1.0 - th * th;
    0.5 * (1.0 + th) + 0.5 * x * sech2 * GELU_C * (1.0 + 3.0 * GELU_K * x * x)
}

pub const LN_EPS: f32 = 1e-5;

/// Re-zero `buf` to exactly `n` elements, keeping its allocation. The
/// workspace idiom: `clear` drops the length without touching capacity, so
/// after warm-up `resize` never reallocates.
pub fn reset(buf: &mut Vec<f32>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

/// LayerNorm over `rows` rows of width `d`; returns `(y, mean, inv_std)`.
/// The training path keeps mean/inv for its backward; decode ignores them.
pub fn layernorm_stats(
    x: &[f32],
    scale: &[f32],
    bias: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (mut y, mut mean, mut inv) = (Vec::new(), Vec::new(), Vec::new());
    layernorm_stats_into(x, scale, bias, rows, d, &mut y, &mut mean, &mut inv);
    (y, mean, inv)
}

/// [`layernorm_stats`] writing into caller-owned buffers (resized here),
/// so the train workspace reuses its allocations every step.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_stats_into(
    x: &[f32],
    scale: &[f32],
    bias: &[f32],
    rows: usize,
    d: usize,
    y: &mut Vec<f32>,
    mean: &mut Vec<f32>,
    inv: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), rows * d);
    reset(y, rows * d);
    reset(inv, rows);
    reset(mean, rows);
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        mean[r] = mu;
        inv[r] = iv;
        let out = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            out[j] = (row[j] - mu) * iv * scale[j] + bias[j];
        }
    }
}

/// LayerNorm returning only the normalised output (the decode hot path).
pub fn layernorm_rows(x: &[f32], scale: &[f32], bias: &[f32], rows: usize, d: usize) -> Vec<f32> {
    layernorm_stats(x, scale, bias, rows, d).0
}

// ---------------------------------------------------------------------------
// Causal multi-head attention (full window + incremental decode step)

/// Causal attention over a full `[b, s]` window. `q`/`k`/`v` are `[b, s, d]`
/// with per-head column blocks; fills `probs` `[b, h, s, s]` and
/// accumulates into `ctx` `[b, s, d]` (callers pass zeroed buffers).
/// Parallel over batch rows: each row's output block is independent.
pub fn attention_forward(
    b: usize,
    s: usize,
    h: usize,
    hd: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &mut [f32],
    ctx: &mut [f32],
) {
    let d = h * hd;
    debug_assert_eq!(probs.len(), b * h * s * s);
    debug_assert_eq!(ctx.len(), b * s * d);
    if !parallel_ok(b, b * h * s * s * hd) {
        for bi in 0..b {
            attention_forward_row(
                s,
                h,
                hd,
                &q[bi * s * d..(bi + 1) * s * d],
                &k[bi * s * d..(bi + 1) * s * d],
                &v[bi * s * d..(bi + 1) * s * d],
                &mut probs[bi * h * s * s..(bi + 1) * h * s * s],
                &mut ctx[bi * s * d..(bi + 1) * s * d],
            );
        }
        return;
    }
    let pp = SendPtr(probs.as_mut_ptr());
    let cp = SendPtr(ctx.as_mut_ptr());
    run_chunks(b, &|bi: usize| {
        // SAFETY: chunk `bi` touches only batch row `bi`'s disjoint slices.
        let probs =
            unsafe { std::slice::from_raw_parts_mut(pp.0.add(bi * h * s * s), h * s * s) };
        let ctx = unsafe { std::slice::from_raw_parts_mut(cp.0.add(bi * s * d), s * d) };
        attention_forward_row(
            s,
            h,
            hd,
            &q[bi * s * d..(bi + 1) * s * d],
            &k[bi * s * d..(bi + 1) * s * d],
            &v[bi * s * d..(bi + 1) * s * d],
            probs,
            ctx,
        );
    });
}

/// One batch row of causal attention (`q`/`k`/`v` row-local `[s, d]`).
fn attention_forward_row(
    s: usize,
    h: usize,
    hd: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &mut [f32],
    ctx: &mut [f32],
) {
    let d = h * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores: Vec<f32> = Vec::with_capacity(s);
    for hh in 0..h {
        let col = hh * hd;
        for i in 0..s {
            let qrow = &q[i * d + col..i * d + col + hd];
            let prow_base = (hh * s + i) * s;
            let mut mx = f32::NEG_INFINITY;
            scores.clear();
            for j in 0..=i {
                let krow = &k[j * d + col..j * d + col + hd];
                let mut acc = 0.0f32;
                for t in 0..hd {
                    acc += qrow[t] * krow[t];
                }
                let sc = acc * scale;
                mx = mx.max(sc);
                scores.push(sc);
            }
            let mut denom = 0.0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            let crow = &mut ctx[i * d + col..i * d + col + hd];
            for j in 0..=i {
                let pj = scores[j] / denom;
                probs[prow_base + j] = pj;
                let vrow = &v[j * d + col..j * d + col + hd];
                for t in 0..hd {
                    crow[t] += pj * vrow[t];
                }
            }
        }
    }
}

/// Backward of [`attention_forward`]: given `dctx` `[b, s, d]` and the
/// forward's `probs`/`q`/`k`/`v`, accumulates into `dq`/`dk`/`dv`
/// (zeroed by the caller). Parallel over batch rows.
pub fn attention_backward(
    b: usize,
    s: usize,
    h: usize,
    hd: usize,
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dctx: &[f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    let d = h * hd;
    if !parallel_ok(b, 2 * b * h * s * s * hd) {
        for bi in 0..b {
            attention_backward_row(
                s,
                h,
                hd,
                &probs[bi * h * s * s..(bi + 1) * h * s * s],
                &q[bi * s * d..(bi + 1) * s * d],
                &k[bi * s * d..(bi + 1) * s * d],
                &v[bi * s * d..(bi + 1) * s * d],
                &dctx[bi * s * d..(bi + 1) * s * d],
                &mut dq[bi * s * d..(bi + 1) * s * d],
                &mut dk[bi * s * d..(bi + 1) * s * d],
                &mut dv[bi * s * d..(bi + 1) * s * d],
            );
        }
        return;
    }
    let qp = SendPtr(dq.as_mut_ptr());
    let kp = SendPtr(dk.as_mut_ptr());
    let vp = SendPtr(dv.as_mut_ptr());
    run_chunks(b, &|bi: usize| {
        // SAFETY: chunk `bi` touches only batch row `bi`'s disjoint slices.
        let dqc = unsafe { std::slice::from_raw_parts_mut(qp.0.add(bi * s * d), s * d) };
        let dkc = unsafe { std::slice::from_raw_parts_mut(kp.0.add(bi * s * d), s * d) };
        let dvc = unsafe { std::slice::from_raw_parts_mut(vp.0.add(bi * s * d), s * d) };
        attention_backward_row(
            s,
            h,
            hd,
            &probs[bi * h * s * s..(bi + 1) * h * s * s],
            &q[bi * s * d..(bi + 1) * s * d],
            &k[bi * s * d..(bi + 1) * s * d],
            &v[bi * s * d..(bi + 1) * s * d],
            &dctx[bi * s * d..(bi + 1) * s * d],
            dqc,
            dkc,
            dvc,
        );
    });
}

fn attention_backward_row(
    s: usize,
    h: usize,
    hd: usize,
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dctx: &[f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    let d = h * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dprobs_row = vec![0.0f32; s];
    for hh in 0..h {
        let col = hh * hd;
        for i in 0..s {
            let prow_base = (hh * s + i) * s;
            let dcrow = &dctx[i * d + col..i * d + col + hd];
            // dprobs and dv.
            let mut rowdot = 0.0f32;
            for j in 0..=i {
                let pj = probs[prow_base + j];
                let vrow = &v[j * d + col..j * d + col + hd];
                let mut acc = 0.0f32;
                for t in 0..hd {
                    acc += dcrow[t] * vrow[t];
                }
                dprobs_row[j] = acc;
                rowdot += acc * pj;
                let dvrow = &mut dv[j * d + col..j * d + col + hd];
                for t in 0..hd {
                    dvrow[t] += pj * dcrow[t];
                }
            }
            // dscores -> dq, dk.
            let q_start = i * d + col;
            for j in 0..=i {
                let pj = probs[prow_base + j];
                let dscore = pj * (dprobs_row[j] - rowdot) * scale;
                if dscore == 0.0 {
                    continue;
                }
                let k_start = j * d + col;
                for t in 0..hd {
                    dq[q_start + t] += dscore * k[k_start + t];
                    dk[k_start + t] += dscore * q[q_start + t];
                }
            }
        }
    }
}

/// One incremental decode step of causal attention: each row's single query
/// at position `pos` attends over its `pos + 1` cached keys. `q` is
/// `[rows, d]`; `kcache`/`vcache` are `[rows, cap, d]`; accumulates into
/// `ctx` `[rows, d]` (zeroed by the caller). Parallel over rows.
pub fn attention_decode_step(
    rows: usize,
    cap: usize,
    pos: usize,
    h: usize,
    hd: usize,
    q: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    ctx: &mut [f32],
) {
    let d = h * hd;
    debug_assert!(pos < cap);
    debug_assert_eq!(q.len(), rows * d);
    debug_assert!(kcache.len() >= rows * cap * d);
    debug_assert_eq!(ctx.len(), rows * d);
    if !parallel_ok(rows, rows * (pos + 1) * d) {
        for r in 0..rows {
            attention_decode_row(
                cap,
                pos,
                h,
                hd,
                &q[r * d..(r + 1) * d],
                &kcache[r * cap * d..(r + 1) * cap * d],
                &vcache[r * cap * d..(r + 1) * cap * d],
                &mut ctx[r * d..(r + 1) * d],
            );
        }
        return;
    }
    let cp = SendPtr(ctx.as_mut_ptr());
    run_chunks(rows, &|r: usize| {
        // SAFETY: chunk `r` writes only row `r`'s disjoint ctx slice.
        let crow = unsafe { std::slice::from_raw_parts_mut(cp.0.add(r * d), d) };
        attention_decode_row(
            cap,
            pos,
            h,
            hd,
            &q[r * d..(r + 1) * d],
            &kcache[r * cap * d..(r + 1) * cap * d],
            &vcache[r * cap * d..(r + 1) * cap * d],
            crow,
        );
    });
}

/// One row of decode attention (`q` `[d]`, caches `[cap, d]`, `ctx` `[d]`).
/// Same online-softmax arithmetic (and scalar order) as the full-window
/// kernel at position `pos`, so session logits match full-forward decode.
fn attention_decode_row(
    cap: usize,
    pos: usize,
    h: usize,
    hd: usize,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    ctx: &mut [f32],
) {
    debug_assert!(pos < cap);
    let d = h * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores: Vec<f32> = Vec::with_capacity(pos + 1);
    for hh in 0..h {
        let col = hh * hd;
        let qrow = &q[col..col + hd];
        let mut mx = f32::NEG_INFINITY;
        scores.clear();
        for j in 0..=pos {
            let krow = &kc[j * d + col..j * d + col + hd];
            let mut acc = 0.0f32;
            for t in 0..hd {
                acc += qrow[t] * krow[t];
            }
            let sc = acc * scale;
            mx = mx.max(sc);
            scores.push(sc);
        }
        let mut denom = 0.0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - mx).exp();
            denom += *sc;
        }
        let crow = &mut ctx[col..col + hd];
        for j in 0..=pos {
            let pj = scores[j] / denom;
            let vrow = &vc[j * d + col..j * d + col + hd];
            for t in 0..hd {
                crow[t] += pj * vrow[t];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Serialises tests that toggle or depend on the process-global
    /// `force_serial` flag (unit tests in this binary run concurrently).
    static SERIAL_GUARD: Mutex<()> = Mutex::new(());

    fn serial_guard() -> std::sync::MutexGuard<'static, ()> {
        SERIAL_GUARD.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    /// Textbook triple-loop reference.
    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a[i * k + p] as f64 * b[p * n + j] as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn pool_runs_borrowed_jobs_to_completion() {
        let mut out = vec![0u32; 64];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, chunk) in out.chunks_mut(8).enumerate() {
                jobs.push(Box::new(move || {
                    for (j, c) in chunk.iter_mut().enumerate() {
                        *c = (i * 8 + j) as u32;
                    }
                }));
            }
            pool().run(jobs);
        }
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn pool_propagates_job_panics() {
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("boom")),
                Box::new(|| {}),
            ];
            pool().run(jobs);
        }));
        // Single-worker pools run inline and propagate directly; multi-worker
        // pools re-panic from the latch. Either way the caller sees a panic.
        assert!(res.is_err());
    }

    /// Satellite regression: with >= 2 workers, N queued jobs must be *in
    /// flight simultaneously* — the old channel path blocked every worker on
    /// the shared receiver mutex during `recv`, serialising hand-offs.
    #[test]
    fn pool_jobs_make_progress_concurrently() {
        let _g = serial_guard();
        set_force_serial(false);
        if pool().workers() < 2 {
            return; // nothing to prove on a serial pool
        }
        let arrived = AtomicUsize::new(0);
        let t0 = std::time::Instant::now();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for _ in 0..2 {
            jobs.push(Box::new(|| {
                arrived.fetch_add(1, Ordering::SeqCst);
                // Each job waits (bounded) for the other: only concurrent
                // execution lets both exit.
                while arrived.load(Ordering::SeqCst) < 2 {
                    assert!(
                        t0.elapsed() < std::time::Duration::from_secs(30),
                        "queued jobs never ran concurrently"
                    );
                    std::thread::yield_now();
                }
            }));
        }
        pool().run(jobs);
        assert_eq!(arrived.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn run_chunks_covers_every_index_exactly_once() {
        let counts: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        run_chunks(97, &|i: usize| {
            counts[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "chunk {i} ran a wrong number of times");
        }
    }

    #[test]
    fn run_chunks_propagates_panics() {
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            run_chunks(8, &|i: usize| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err());
    }

    #[test]
    fn matmul_matches_naive_and_is_thread_invariant() {
        let _g = serial_guard();
        let mut rng = Pcg64::from_seed(1);
        // Large enough to cross both the small-GEMM and parallel thresholds
        // on multicore hosts, with ragged tails in every dimension.
        let (m, k, n) = (97, 67, 51);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        set_force_serial(false);
        let c = matmul(&a, &b, m, k, n);
        let reference = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&reference) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        set_force_serial(true);
        let c_serial = matmul(&a, &b, m, k, n);
        set_force_serial(false);
        assert_eq!(c, c_serial, "threaded matmul must be bit-identical to serial");
    }

    #[test]
    fn matmul_set_overwrites_garbage_and_matches_acc_from_zero() {
        let mut rng = Pcg64::from_seed(7);
        let (m, k, n) = (33, 40, 21);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut c_set = vec![f32::NAN; m * n]; // must be fully overwritten
        matmul_set(&mut c_set, &a, &b, m, k, n);
        let mut c_acc = vec![0.0f32; m * n];
        matmul_acc(&mut c_acc, &a, &b, m, k, n);
        assert_eq!(c_set, c_acc, "set variant must equal acc-from-zero bit-for-bit");
    }

    #[test]
    fn fused_bias_gelu_matches_unfused() {
        let mut rng = Pcg64::from_seed(8);
        let (m, k, n) = (26, 35, 29);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bias = randv(&mut rng, n);
        let mut pre = vec![0.0f32; m * n];
        let mut act = vec![0.0f32; m * n];
        matmul_set_bias_gelu(&mut pre, &mut act, &a, &b, &bias, m, k, n);

        let mut expect_pre = matmul(&a, &b, m, k, n);
        for r in 0..m {
            for j in 0..n {
                expect_pre[r * n + j] += bias[j];
            }
        }
        let expect_act: Vec<f32> = expect_pre.iter().map(|&z| gelu(z)).collect();
        assert_eq!(pre, expect_pre, "fused pre-activation diverged");
        assert_eq!(act, expect_act, "fused activation diverged");
    }

    #[test]
    fn packed_matmul_matches_unpacked_bitwise() {
        let mut rng = Pcg64::from_seed(9);
        // One shape under the small-GEMM threshold, one over it: the packed
        // entry always runs blocked, and must still match both.
        for (m, k, n) in [(3usize, 19usize, 11usize), (70, 64, 50)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let unpacked = matmul(&a, &b, m, k, n);
            let packed = PackedB::pack(&b, k, n);
            assert_eq!(packed.k(), k);
            assert_eq!(packed.n(), n);
            let mut c = vec![f32::NAN; m * n];
            matmul_set_packed(&mut c, &a, &packed, m);
            assert_eq!(c, unpacked, "packed path diverged at {m}x{k}x{n}");
        }
    }

    #[test]
    fn at_b_and_a_bt_match_transposed_naive() {
        let mut rng = Pcg64::from_seed(2);
        let (m, k, n) = (40, 96, 32);
        // c[m,n] += aᵀ·b with a: [k,m].
        let a = randv(&mut rng, k * m);
        let b = randv(&mut rng, k * n);
        let mut c = vec![0.0f32; m * n];
        matmul_at_b_acc(&mut c, &a, &b, k, m, n);
        let mut at = vec![0.0f32; m * k];
        for p in 0..k {
            for i in 0..m {
                at[i * k + p] = a[p * m + i];
            }
        }
        let reference = naive_matmul(&at, &b, m, k, n);
        for (x, y) in c.iter().zip(&reference) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }

        // c[m,n] += a·bᵀ with b: [n,k].
        let a2 = randv(&mut rng, m * k);
        let b2 = randv(&mut rng, n * k);
        let mut c2 = vec![0.0f32; m * n];
        matmul_a_bt_acc(&mut c2, &a2, &b2, m, k, n);
        let mut b2t = vec![0.0f32; k * n];
        for j in 0..n {
            for p in 0..k {
                b2t[p * n + j] = b2[j * k + p];
            }
        }
        let reference2 = naive_matmul(&a2, &b2t, m, k, n);
        for (x, y) in c2.iter().zip(&reference2) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn decode_attention_matches_full_window_last_position() {
        let mut rng = Pcg64::from_seed(3);
        let (b, s, h, hd) = (3, 6, 2, 4);
        let d = h * hd;
        let q = randv(&mut rng, b * s * d);
        let k = randv(&mut rng, b * s * d);
        let v = randv(&mut rng, b * s * d);
        let mut probs = vec![0.0f32; b * h * s * s];
        let mut ctx = vec![0.0f32; b * s * d];
        attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);

        // Same data laid out as decode caches [rows, cap, d]; query = last pos.
        let pos = s - 1;
        let mut qlast = vec![0.0f32; b * d];
        for r in 0..b {
            qlast[r * d..(r + 1) * d].copy_from_slice(&q[(r * s + pos) * d..(r * s + pos + 1) * d]);
        }
        let mut ctx_step = vec![0.0f32; b * d];
        attention_decode_step(b, s, pos, h, hd, &qlast, &k, &v, &mut ctx_step);
        for r in 0..b {
            let full = &ctx[(r * s + pos) * d..(r * s + pos + 1) * d];
            let step = &ctx_step[r * d..(r + 1) * d];
            assert_eq!(full, step, "row {r}: decode-step attention diverged");
        }
    }

    #[test]
    fn simd_and_scalar_tiles_bit_identical() {
        let _g = serial_guard();
        if !simd_available() {
            eprintln!("skipping: no AVX2 on this host");
            return;
        }
        let mut rng = Pcg64::from_seed(11);
        // Ragged in every dimension, over the small-GEMM threshold.
        let (m, k, n) = (37, 300, 23);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bias = randv(&mut rng, n);
        let mut results: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = Vec::new();
        for isa in [KernelIsa::Scalar, KernelIsa::Avx2] {
            set_kernel_override(Some(isa));
            let c = matmul(&a, &b, m, k, n);
            let mut pre = vec![f32::NAN; m * n];
            let mut act = vec![f32::NAN; m * n];
            matmul_set_bias_gelu(&mut pre, &mut act, &a, &b, &bias, m, k, n);
            results.push((c, pre, act));
        }
        set_kernel_override(None);
        assert_eq!(results[0].0, results[1].0, "scalar vs SIMD matmul diverged");
        assert_eq!(results[0].1, results[1].1, "scalar vs SIMD fused pre diverged");
        assert_eq!(results[0].2, results[1].2, "scalar vs SIMD fused act diverged");
    }

    #[test]
    fn multi_b_matches_three_single_calls_bitwise() {
        let _g = serial_guard();
        let mut rng = Pcg64::from_seed(12);
        // One shape under the small-GEMM threshold, one blocked + ragged.
        for (m, k, n) in [(5usize, 9usize, 7usize), (37, 300, 23)] {
            let a = randv(&mut rng, m * k);
            let bs: Vec<Vec<f32>> = (0..MULTI_B).map(|_| randv(&mut rng, k * n)).collect();

            // matmul_set_multi vs three matmul_set calls (NaN-initialised:
            // the set path must fully overwrite).
            let mut single: Vec<Vec<f32>> = (0..MULTI_B).map(|_| vec![f32::NAN; m * n]).collect();
            for (c, b) in single.iter_mut().zip(bs.iter()) {
                matmul_set(c, &a, b, m, k, n);
            }
            let mut multi: Vec<Vec<f32>> = (0..MULTI_B).map(|_| vec![f32::NAN; m * n]).collect();
            {
                let (c0, rest) = multi.split_first_mut().unwrap();
                let (c1, rest) = rest.split_first_mut().unwrap();
                let c2 = &mut rest[0];
                matmul_set_multi(
                    [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                    &a,
                    [&bs[0], &bs[1], &bs[2]],
                    m,
                    k,
                    n,
                );
            }
            assert_eq!(single, multi, "matmul_set_multi diverged at {m}x{k}x{n}");

            // matmul_at_b_acc_multi vs three singles, from a seeded (nonzero)
            // accumulator.
            let at = randv(&mut rng, k * m);
            let seed: Vec<Vec<f32>> = (0..MULTI_B).map(|_| randv(&mut rng, m * n)).collect();
            let mut single_acc = seed.clone();
            for (c, b) in single_acc.iter_mut().zip(bs.iter()) {
                matmul_at_b_acc(c, &at, b, k, m, n);
            }
            let mut multi_acc = seed.clone();
            {
                let (c0, rest) = multi_acc.split_first_mut().unwrap();
                let (c1, rest) = rest.split_first_mut().unwrap();
                let c2 = &mut rest[0];
                matmul_at_b_acc_multi(
                    [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                    &at,
                    [&bs[0], &bs[1], &bs[2]],
                    k,
                    m,
                    n,
                );
            }
            assert_eq!(single_acc, multi_acc, "matmul_at_b_acc_multi diverged at {m}x{k}x{n}");

            // matmul_set_packed_multi vs single packed calls.
            let packed: Vec<PackedB> = bs.iter().map(|b| PackedB::pack(b, k, n)).collect();
            let mut multi_packed: Vec<Vec<f32>> =
                (0..MULTI_B).map(|_| vec![f32::NAN; m * n]).collect();
            {
                let (c0, rest) = multi_packed.split_first_mut().unwrap();
                let (c1, rest) = rest.split_first_mut().unwrap();
                let c2 = &mut rest[0];
                matmul_set_packed_multi(
                    [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                    &a,
                    [&packed[0], &packed[1], &packed[2]],
                    m,
                );
            }
            assert_eq!(single, multi_packed, "matmul_set_packed_multi diverged at {m}x{k}x{n}");
        }
    }

    #[test]
    fn layernorm_rows_matches_stats_output() {
        let mut rng = Pcg64::from_seed(4);
        let (rows, d) = (5, 16);
        let x = randv(&mut rng, rows * d);
        let scale = randv(&mut rng, d);
        let bias = randv(&mut rng, d);
        let (y, mean, inv) = layernorm_stats(&x, &scale, &bias, rows, d);
        assert_eq!(y, layernorm_rows(&x, &scale, &bias, rows, d));
        assert_eq!(mean.len(), rows);
        assert!(inv.iter().all(|&v| v > 0.0));
    }
}
