//! Shared dense-math kernels for the native backend: cache-blocked,
//! register-tiled GEMM microkernels, lane-shaped attention and LayerNorm
//! kernels, plus a small `std::thread` worker pool with low-overhead chunk
//! dispatch.
//!
//! Every kernel here is used by *both* halves of the system: the
//! incremental decode sessions (`super::kv`) and the train/prox
//! forward-backward paths (`super::model`).
//!
//! # GEMM blocking scheme
//!
//! The matmul family packs `b` into contiguous [`NR`]-wide column panels
//! (zero-padded at a ragged right edge), splits `k` into [`KC`]-sized
//! blocks, and computes [`MR`]`x`[`NR`] output tiles in a fixed-size
//! register accumulator. There is no `NC` blocking: each `k` block sweeps
//! all column panels (the widest operand here, `d_ff`/`vocab`, fits
//! comfortably in L2 once packed). The q/k/v projection triple runs through
//! fused multi-`B` entry points ([`matmul_set_multi`],
//! [`matmul_at_b_acc_multi`], [`matmul_set_packed_multi`]) that pack each
//! shared `A` micropanel once and stream it through all three weight
//! panels.
//!
//! # Register tiles and runtime ISA dispatch
//!
//! The `MR x NR` tile has two interchangeable implementations: a portable
//! scalar tile with branch-free loops the compiler autovectorizes, and an
//! explicit AVX2 tile (`std::arch`, 8 f32 lanes = the [`NR`] panel columns)
//! selected once per process when `is_x86_feature_detected!` approves.
//! `A3PO_KERNEL=scalar|simd` overrides the choice, and
//! [`set_kernel_override`] does the same in-process (benches use it for
//! side-by-side timing). The AVX2 tile deliberately uses separate multiply
//! and add instructions rather than `vfmadd`: a fused multiply-add would
//! skip the intermediate rounding the scalar tile performs and break
//! scalar-vs-SIMD bit-equality — the speedup comes from lane width, not
//! from fewer roundings.
//!
//! # Determinism contract
//!
//! Every output element accumulates in an order that is a pure function of
//! the blocking — within each `KC` block, strictly ascending `p`, into a
//! private register sum that is then added to `c` block by block — and
//! *never* a function of the thread count, the chunk partition, the row
//! tile an element lands in, or the selected register tile (padding lanes
//! multiply into separate lanes and are discarded; the SIMD tile replays
//! the scalar tile's per-lane operation sequence exactly). The scalar
//! small-operand path replays the identical per-element operation sequence,
//! and the multi-`B` path reuses only the `A` pack — each output's
//! accumulation order is untouched. Threaded, serial, packed, unpacked,
//! scalar, SIMD, fused-multi-`B`, and any-`A3PO_THREADS` runs are therefore
//! bit-identical; the decode/train parity suites and
//! `tests/kernel_parity.rs` pin this.
//!
//! The attention and LayerNorm kernels extend the same contract beyond the
//! GEMMs: their dot, max, sum, and normalise passes run in a fixed 8-lane
//! partial-sum shape (see the lane primitives section) with scalar and AVX2
//! twins that replay one per-lane operation order, the softmax `exp` is
//! scalar libm on *every* path (both twins share one function, so there is
//! no vector-exp approximation to diverge), and attention parallelises over
//! (batch row × head) work units that own disjoint output stripes — so the
//! unit grain, like the chunk partition, can never change a result.
//!
//! # Dispatch
//!
//! A run is a shared atomic chunk counter over pre-partitioned row ranges:
//! workers (and the calling thread — it runs chunks instead of idling on
//! the completion latch) claim chunk indices with one `fetch_add` each, so
//! there is no per-job heap allocation and no channel. The legacy
//! `Vec<Box<dyn FnOnce>>` batch API ([`WorkerPool::run`]) remains for
//! irregular job shapes, now feeding the same shared queue: jobs are
//! enqueued under one short-lived lock and workers block on a condvar (not
//! on a channel-receiver mutex), so dequeues never serialise.
//!
//! Pool sizing: `A3PO_THREADS` overrides; the default is
//! `available_parallelism` capped at [`MAX_THREADS`]. Kernels fall back to
//! the serial path for small operands (below [`PAR_MIN_WORK`] multiply-adds)
//! where fan-out overhead would dominate, or when
//! [`set_force_serial`]`(true)` is active (benches use this to measure the
//! threading speedup in-process).

#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Hard cap on pool size (beyond this, the tiny matmuls here stop scaling).
pub const MAX_THREADS: usize = 16;

/// Minimum multiply-add count before a kernel fans out to the pool.
const PAR_MIN_WORK: usize = 1 << 17;

static FORCE_SERIAL: AtomicBool = AtomicBool::new(false);

/// Force every kernel onto the serial path (process-global). Results are
/// identical either way; benches toggle this to isolate the thread-pool
/// contribution to throughput.
pub fn set_force_serial(v: bool) {
    FORCE_SERIAL.store(v, Ordering::SeqCst);
}

pub fn force_serial() -> bool {
    FORCE_SERIAL.load(Ordering::SeqCst)
}

// ---------------------------------------------------------------------------
// Worker pool

type Job = Box<dyn FnOnce() + Send + 'static>;

/// One counter-claimed chunked run (see [`run_chunks`]). Workers claim chunk
/// indices with a single `fetch_add`; no allocation happens per chunk.
struct RunTask {
    next: AtomicUsize,
    n_chunks: usize,
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
    /// The chunk body with its borrow lifetime erased. Only dereferenced
    /// for claimed indices `< n_chunks`, all of which complete before
    /// [`run_chunks`] returns — so every call happens while the original
    /// closure is alive.
    func: *const (dyn Fn(usize) + Sync),
}

// SAFETY: `func` is only called between enqueue and latch-release inside
// `run_chunks`, while the pointee is borrowed by the blocked caller; all
// other fields are Sync synchronisation primitives.
unsafe impl Send for RunTask {}
unsafe impl Sync for RunTask {}

impl RunTask {
    fn is_exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.n_chunks
    }

    /// Claim and run chunks until none remain. Called by workers *and* by
    /// the submitting thread.
    fn work(&self) {
        loop {
            let idx = self.next.fetch_add(1, Ordering::Relaxed);
            if idx >= self.n_chunks {
                return;
            }
            // SAFETY: see the `func` field invariant above.
            let func = unsafe { &*self.func };
            if catch_unwind(AssertUnwindSafe(|| func(idx))).is_err() {
                self.panicked.store(true, Ordering::SeqCst);
            }
            let mut g = self.remaining.lock().unwrap();
            *g -= 1;
            if *g == 0 {
                self.cv.notify_all();
            }
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Queue entries: boxed one-shot jobs (the legacy batch API) or shared
/// chunk-claiming tasks.
enum Work {
    Job(Job),
    Task(Arc<RunTask>),
}

struct QueueState {
    items: VecDeque<Work>,
    shutdown: bool,
}

struct Shared {
    q: Mutex<QueueState>,
    cv: Condvar,
}

enum WorkItem {
    Job(Job),
    Task(Arc<RunTask>),
}

fn worker_loop(shared: Arc<Shared>) {
    enum Take {
        PopExhausted,
        Task(Arc<RunTask>),
        Job,
        Empty,
    }
    loop {
        let item = {
            let mut g = shared.q.lock().unwrap();
            loop {
                let take = match g.items.front() {
                    Some(Work::Task(t)) => {
                        if t.is_exhausted() {
                            Take::PopExhausted
                        } else {
                            // Leave the task at the front so every idle
                            // worker keeps helping until it is exhausted.
                            Take::Task(t.clone())
                        }
                    }
                    Some(Work::Job(_)) => Take::Job,
                    None => Take::Empty,
                };
                match take {
                    Take::PopExhausted => {
                        g.items.pop_front();
                    }
                    Take::Task(t) => break Some(WorkItem::Task(t)),
                    Take::Job => {
                        if let Some(Work::Job(job)) = g.items.pop_front() {
                            break Some(WorkItem::Job(job));
                        }
                    }
                    Take::Empty => {
                        if g.shutdown {
                            break None;
                        }
                        g = shared.cv.wait(g).unwrap();
                    }
                }
            }
        };
        match item {
            Some(WorkItem::Task(t)) => t.work(),
            Some(WorkItem::Job(job)) => job(),
            None => return,
        }
    }
}

/// Completion is signalled from a `Drop` guard so a panicking job still
/// releases the caller instead of deadlocking `Latch::wait`.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn complete_one(&self) {
        let mut g = self.remaining.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

struct DoneGuard {
    latch: Arc<Latch>,
}

impl Drop for DoneGuard {
    fn drop(&mut self) {
        self.latch.complete_one();
    }
}

/// A fixed set of persistent worker threads over one shared work queue.
pub struct WorkerPool {
    workers: usize,
    shared: Option<Arc<Shared>>,
}

impl WorkerPool {
    fn new(workers: usize) -> WorkerPool {
        if workers <= 1 {
            return WorkerPool { workers: 1, shared: None };
        }
        let shared = Arc::new(Shared {
            q: Mutex::new(QueueState { items: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        for i in 0..workers {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name(format!("a3po-kernel-{i}"))
                .spawn(move || worker_loop(shared))
                .expect("spawning kernel worker");
        }
        WorkerPool { workers, shared: Some(shared) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    fn push_task(&self, task: Arc<RunTask>) {
        let shared = self.shared.as_ref().expect("push_task on a serial pool");
        {
            let mut g = shared.q.lock().unwrap();
            g.items.push_back(Work::Task(task));
        }
        shared.cv.notify_all();
    }

    /// Drop a finished task that no worker happened to pop yet.
    fn remove_task(&self, task: &Arc<RunTask>) {
        let shared = self.shared.as_ref().expect("remove_task on a serial pool");
        let mut g = shared.q.lock().unwrap();
        g.items.retain(|w| !matches!(w, Work::Task(t) if Arc::ptr_eq(t, task)));
    }

    /// Run a batch of jobs, blocking until every one has finished. Jobs may
    /// borrow from the caller's stack: the blocking wait is what makes the
    /// internal lifetime erasure sound. Panics if any job panicked.
    ///
    /// Jobs are appended to the shared queue under one short-lived lock and
    /// picked up by condvar-blocked workers, so N jobs are in flight
    /// concurrently as soon as N workers wake (the old channel path sent
    /// while holding a sender mutex and workers blocked in `recv` holding
    /// the receiver mutex, serialising every hand-off).
    pub fn run<'a>(&self, mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        match jobs.len() {
            0 => return,
            1 => {
                (jobs.pop().unwrap())();
                return;
            }
            _ => {}
        }
        let shared = match &self.shared {
            Some(shared) if !force_serial() => shared,
            _ => {
                for job in jobs {
                    job();
                }
                return;
            }
        };
        let latch = Arc::new(Latch {
            remaining: Mutex::new(jobs.len()),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut g = shared.q.lock().unwrap();
            for job in jobs {
                // SAFETY: `run` blocks on the latch until every submitted
                // job has completed (the Drop guard fires even on panic), so
                // all borrows captured in `job` strictly outlive its
                // execution. Only the lifetime is erased; the layout of the
                // boxed trait object is unchanged.
                let job: Job = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'a>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(job)
                };
                let latch = latch.clone();
                g.items.push_back(Work::Job(Box::new(move || {
                    let guard = DoneGuard { latch };
                    if catch_unwind(AssertUnwindSafe(job)).is_err() {
                        guard.latch.panicked.store(true, Ordering::SeqCst);
                    }
                    drop(guard);
                })));
            }
        }
        shared.cv.notify_all();
        latch.wait();
        if latch.panicked.load(Ordering::SeqCst) {
            panic!("a kernel worker job panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            {
                let mut g = shared.q.lock().unwrap();
                g.shutdown = true;
            }
            shared.cv.notify_all();
        }
    }
}

/// The process-global kernel pool (created on first use).
pub fn pool() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(configured_threads()))
}

/// The pool size this process uses, computed *without* constructing the
/// pool — logging and bench-metadata callers must not spawn the worker
/// threads as a side effect of asking.
pub fn configured_threads() -> usize {
    std::env::var("A3PO_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
        .clamp(1, MAX_THREADS)
}

/// Run `f(0..n_chunks)` with chunks claimed off a shared atomic counter by
/// the pool workers *and* the calling thread. Chunk bodies must write only
/// disjoint state (the kernels slice disjoint output rows). Blocks until
/// every chunk has run; panics if any chunk panicked. Results must not
/// depend on which thread runs which chunk — the kernels guarantee this by
/// making accumulation order a pure function of the blocking.
pub fn run_chunks(n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
    if n_chunks == 0 {
        return;
    }
    // `force_serial()` before `pool()`: serial benches and one-shot tests
    // must not spawn the worker threads as a side effect of the check.
    if n_chunks == 1 || force_serial() || pool().workers() <= 1 {
        for i in 0..n_chunks {
            f(i);
        }
        return;
    }
    // Coarse dispatch span (parallel path only): one per fan-out, recorded
    // on the calling thread — pool workers never touch the trace recorder.
    let _sp = crate::trace::span_arg("run_chunks", "kernel", "chunks", n_chunks as f64);
    // SAFETY: the borrow of `f` is erased, but `run_chunks` blocks on the
    // latch until every claimed chunk has finished, and workers never call
    // the closure for indices >= n_chunks — so no call outlives `f`.
    let func = unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
    };
    let task = Arc::new(RunTask {
        next: AtomicUsize::new(0),
        n_chunks,
        remaining: Mutex::new(n_chunks),
        cv: Condvar::new(),
        panicked: AtomicBool::new(false),
        func,
    });
    pool().push_task(task.clone());
    // The caller claims chunks too instead of idling on the latch.
    task.work();
    task.wait();
    pool().remove_task(&task);
    if task.panicked.load(Ordering::SeqCst) {
        panic!("a kernel worker job panicked");
    }
}

/// Should an op of `work` multiply-adds with `rows` splittable rows fan out?
fn parallel_ok(rows: usize, work: usize) -> bool {
    // `force_serial()` before `pool()` so forced-serial callers never spawn
    // the worker threads as a side effect of asking.
    rows >= 2 && work >= PAR_MIN_WORK && !force_serial() && pool().workers() >= 2
}

/// Raw mutable base pointer, `Send + Sync` so disjoint row ranges of one
/// output buffer can be sliced per-chunk inside a `Fn(usize)` closure.
/// Soundness: every user derives non-overlapping slices from it.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);

unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

// ---------------------------------------------------------------------------
// Blocked GEMM microkernels

/// Register-tile rows: the microkernel accumulates an `MR x NR` output tile.
pub const MR: usize = 4;
/// Register-tile columns (8 f32 lanes — two SSE registers or one AVX).
pub const NR: usize = 8;
/// k-dimension cache block: one packed `B` panel column (`KC·NR` floats)
/// plus the `A` micropanel (`MR·KC` floats, on the stack) stay L1-resident.
pub const KC: usize = 256;

/// Below this many multiply-adds the pack pass costs more than blocking
/// saves; a scalar path that replays the identical per-element operation
/// order runs instead (results are bit-identical either way).
const SMALL_GEMM_WORK: usize = 1 << 13;

#[allow(clippy::manual_div_ceil)] // usize::div_ceil needs rustc >= 1.73
fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

// ---------------------------------------------------------------------------
// Register-tile ISA selection (runtime dispatch)

/// Which implementation of the `MR x NR` register tile executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelIsa {
    /// Portable scalar tile (autovectorized by the compiler).
    Scalar,
    /// Explicit `std::arch` AVX2 tile (x86-64, runtime-detected).
    Avx2,
}

impl KernelIsa {
    pub fn name(self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Avx2 => "avx2",
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn simd_available_impl() -> bool {
    std::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn simd_available_impl() -> bool {
    false
}

/// Can this host execute the SIMD register tile? (`std` caches detection.)
pub fn simd_available() -> bool {
    simd_available_impl()
}

/// In-process override: 0 = follow `A3PO_KERNEL` / detection, 1 = scalar,
/// 2 = SIMD-if-available.
static KERNEL_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force a register tile in-process (process-global), mirroring
/// [`set_force_serial`]: benches and parity tests toggle it to compare the
/// scalar and SIMD tiles without re-execing. Results are bit-identical
/// either way. `Some(Avx2)` on a host without AVX2 falls back to scalar.
pub fn set_kernel_override(isa: Option<KernelIsa>) {
    let v = match isa {
        None => 0,
        Some(KernelIsa::Scalar) => 1,
        Some(KernelIsa::Avx2) => 2,
    };
    KERNEL_OVERRIDE.store(v, Ordering::SeqCst);
}

/// The `(isa, forced_by_env)` choice from `A3PO_KERNEL` / detection, read
/// once per process (like `A3PO_THREADS`: per-process pinning is what makes
/// the cross-process parity checks meaningful).
fn env_choice() -> (KernelIsa, bool) {
    static CHOICE: OnceLock<(KernelIsa, bool)> = OnceLock::new();
    *CHOICE.get_or_init(|| {
        let detected = if simd_available() { KernelIsa::Avx2 } else { KernelIsa::Scalar };
        match std::env::var("A3PO_KERNEL").ok().as_deref() {
            Some("scalar") => (KernelIsa::Scalar, true),
            Some("simd") => {
                if simd_available() {
                    (KernelIsa::Avx2, true)
                } else {
                    eprintln!("a3po: A3PO_KERNEL=simd but this host lacks AVX2; using scalar");
                    (KernelIsa::Scalar, true)
                }
            }
            Some(other) => {
                eprintln!(
                    "a3po: unrecognised A3PO_KERNEL={other:?} (expected scalar|simd); \
                     auto-detecting"
                );
                (detected, false)
            }
            None => (detected, false),
        }
    })
}

/// The register tile the next GEMM will run: in-process override first,
/// then `A3PO_KERNEL`, then feature detection.
pub fn active_isa() -> KernelIsa {
    match KERNEL_OVERRIDE.load(Ordering::SeqCst) {
        1 => KernelIsa::Scalar,
        2 if simd_available() => KernelIsa::Avx2,
        2 => KernelIsa::Scalar,
        _ => env_choice().0,
    }
}

/// Snapshot of the selected kernel path, for startup logging and bench
/// artifact metadata.
#[derive(Clone, Debug)]
pub struct KernelInfo {
    pub isa: KernelIsa,
    pub simd_available: bool,
    /// True when `A3PO_KERNEL` (not auto-detection) picked the tile.
    pub forced_by_env: bool,
    pub mr: usize,
    pub nr: usize,
    pub kc: usize,
    pub threads: usize,
}

pub fn kernel_info() -> KernelInfo {
    let (_, forced_by_env) = env_choice();
    KernelInfo {
        isa: active_isa(),
        simd_available: simd_available(),
        forced_by_env,
        mr: MR,
        nr: NR,
        kc: KC,
        threads: configured_threads(),
    }
}

/// Log the selected kernel path once per process (stderr; `A3PO_QUIET`
/// suppresses it). Called at native backend construction so every train or
/// decode run states which code path produced its numbers.
pub fn log_kernel_path_once() {
    static LOGGED: AtomicBool = AtomicBool::new(false);
    if LOGGED.swap(true, Ordering::SeqCst) || std::env::var_os("A3PO_QUIET").is_some() {
        return;
    }
    let info = kernel_info();
    let how = if info.forced_by_env {
        "A3PO_KERNEL"
    } else if info.simd_available {
        "detected"
    } else {
        "no simd on this host"
    };
    eprintln!(
        "a3po kernels: isa={} ({how}), tile {}x{}x{} (MRxNRxKC), {} threads",
        info.isa.name(),
        info.mr,
        info.nr,
        info.kc,
        info.threads
    );
}

/// How the `a` operand is laid out.
#[derive(Clone, Copy)]
enum AMode {
    /// `a` is `[m, k]` row-major: element `(i, p)` at `a[i*k + p]`.
    Rows,
    /// `a` is `[k, m]` (the `aᵀ·b` gradient variant): `(i, p)` at `a[p*m + i]`.
    Cols,
}

/// Reusable per-thread pack scratch: one buffer per caller thread, grown
/// once and reused across layers, steps, and sessions.
thread_local! {
    static PACK_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Pack `b` into `[k-block][column-panel][p][lane]` order: for each `KC`
/// block, `NR`-wide column panels stored contiguously with ascending `p`
/// inside, zero-padded at a ragged right edge. `bt = true` reads `b` as the
/// `[n, k]` transposed operand of the `a·bᵀ` variant.
fn pack_b_into(dst: &mut Vec<f32>, b: &[f32], k: usize, n: usize, bt: bool) {
    let n_panels = div_ceil(n, NR);
    dst.clear();
    dst.resize(k * n_panels * NR, 0.0);
    pack_b_panels(dst, b, k, n, bt);
}

/// Pack into a pre-zeroed `k * div_ceil(n, NR) * NR` slice (see
/// [`pack_b_into`] for the layout). Ragged-edge padding lanes are *left*
/// untouched, so the caller must hand in zeroed memory — this is what lets
/// the multi-`B` path pack several operands back-to-back in one scratch
/// buffer.
fn pack_b_panels(dst: &mut [f32], b: &[f32], k: usize, n: usize, bt: bool) {
    let n_panels = div_ceil(n, NR);
    let kblocks = div_ceil(k, KC);
    for kb in 0..kblocks {
        let p0 = kb * KC;
        let kcl = KC.min(k - p0);
        let base = kb * KC * n_panels * NR;
        for jp in 0..n_panels {
            let j0 = jp * NR;
            let jn = NR.min(n - j0);
            let panel = &mut dst[base + jp * kcl * NR..base + (jp + 1) * kcl * NR];
            for p in 0..kcl {
                let row = &mut panel[p * NR..(p + 1) * NR];
                if bt {
                    for r in 0..jn {
                        row[r] = b[(j0 + r) * k + (p0 + p)];
                    }
                } else {
                    row[..jn].copy_from_slice(&b[(p0 + p) * n + j0..(p0 + p) * n + j0 + jn]);
                }
                // row[jn..] stays zero: padding lanes accumulate garbage-free
                // into discarded lanes and never touch real output.
            }
        }
    }
}

/// The portable scalar `MR x NR` register tile: branch-free fixed-trip
/// loops the compiler autovectorizes. Each `p` step does one rounded
/// multiply then one rounded add per output lane; the AVX2 tile replays
/// exactly this per-lane operation sequence, so the two are bit-identical.
#[inline(always)]
fn tile_scalar(
    acc: &mut [[f32; NR]; MR],
    apack: &[f32; MR * KC],
    panel: &[f32],
    kcl: usize,
    mr: usize,
) {
    for p in 0..kcl {
        let brow = &panel[p * NR..(p + 1) * NR];
        for r in 0..mr {
            let av = apack[r * KC + p];
            let arow = &mut acc[r];
            for j in 0..NR {
                arow[j] += av * brow[j];
            }
        }
    }
}

/// Explicit AVX2 register tile and lane-shaped vector primitives (selected
/// at runtime; never reached on other architectures).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{reduce_lanes, reduce_max_lanes, KC, MR, NR};
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_div_ps, _mm256_loadu_ps, _mm256_max_ps, _mm256_mul_ps,
        _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps, _mm256_sub_ps,
    };

    // The lane layout below hardcodes the tile geometry.
    const _: () = assert!(MR == 4 && NR == 8, "the AVX2 tile is written for a 4x8 f32 tile");

    /// AVX2 `MR x NR` tile: lane `j` of each 256-bit accumulator is panel
    /// column `j`, and each `p` step performs one rounded multiply
    /// (`vmulps`) then one rounded add (`vaddps`) per lane — deliberately
    /// *not* `vfmadd`: fusing would skip the intermediate rounding the
    /// scalar tile performs and break the scalar ≡ SIMD bit-equality
    /// contract. The win is eight lanes per instruction, not fewer
    /// roundings.
    ///
    /// All `MR` rows are computed unconditionally — on a ragged last row
    /// block the caller zero-fills `apack` rows `mr..MR`, so the extra rows
    /// accumulate zeros into registers whose write-back the caller skips.
    ///
    /// # Safety
    ///
    /// AVX2 must be available (`is_x86_feature_detected!("avx2")`), `panel`
    /// must hold at least `kcl * NR` floats, and `kcl <= KC`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn tile(
        acc: &mut [[f32; NR]; MR],
        apack: &[f32; MR * KC],
        panel: &[f32],
        kcl: usize,
    ) {
        debug_assert!(kcl <= KC);
        debug_assert!(panel.len() >= kcl * NR);
        let mut v0 = _mm256_loadu_ps(acc[0].as_ptr());
        let mut v1 = _mm256_loadu_ps(acc[1].as_ptr());
        let mut v2 = _mm256_loadu_ps(acc[2].as_ptr());
        let mut v3 = _mm256_loadu_ps(acc[3].as_ptr());
        let pp = panel.as_ptr();
        let ap = apack.as_ptr();
        for p in 0..kcl {
            let bv = _mm256_loadu_ps(pp.add(p * NR));
            v0 = _mm256_add_ps(v0, _mm256_mul_ps(_mm256_set1_ps(*ap.add(p)), bv));
            v1 = _mm256_add_ps(v1, _mm256_mul_ps(_mm256_set1_ps(*ap.add(KC + p)), bv));
            v2 = _mm256_add_ps(v2, _mm256_mul_ps(_mm256_set1_ps(*ap.add(2 * KC + p)), bv));
            v3 = _mm256_add_ps(v3, _mm256_mul_ps(_mm256_set1_ps(*ap.add(3 * KC + p)), bv));
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), v0);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), v1);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), v2);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), v3);
    }

    /// Lane-shaped dot product: replays `dot_lanes_scalar` exactly — vector
    /// lane `l` is the scalar twin's `lanes[l]`, each chunk does one rounded
    /// multiply then one rounded add per lane (`vmulps` + `vaddps`, never
    /// `vfmadd`), tail elements land in lanes `0..rem`, and the combine is
    /// the shared ascending-lane reduce.
    ///
    /// # Safety
    ///
    /// AVX2 must be available and `a.len() == b.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / NR;
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let av = _mm256_loadu_ps(ap.add(c * NR));
            let bv = _mm256_loadu_ps(bp.add(c * NR));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(av, bv));
        }
        let mut lanes = [0.0f32; NR];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (l, t) in (chunks * NR..n).enumerate() {
            lanes[l] += *ap.add(t) * *bp.add(t);
        }
        reduce_lanes(&lanes)
    }

    /// `out[t] += a * x[t]`, elementwise — same rounding sequence as the
    /// scalar twin (one multiply, one add per element; no fusing).
    ///
    /// # Safety
    ///
    /// AVX2 must be available and `out.len() == x.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(out: &mut [f32], x: &[f32], a: f32) {
        let n = out.len();
        let chunks = n / NR;
        let av = _mm256_set1_ps(a);
        let op = out.as_mut_ptr();
        let xp = x.as_ptr();
        for c in 0..chunks {
            let xv = _mm256_loadu_ps(xp.add(c * NR));
            let ov = _mm256_loadu_ps(op.add(c * NR));
            _mm256_storeu_ps(op.add(c * NR), _mm256_add_ps(ov, _mm256_mul_ps(av, xv)));
        }
        for t in chunks * NR..n {
            *op.add(t) += a * *xp.add(t);
        }
    }

    /// Lane-shaped max (softmax stabiliser). `vmaxps` agrees with the
    /// scalar `f32::max` on the finite scores the kernels produce; a
    /// sign-of-zero tie could pick the other zero, but the max only feeds
    /// `exp(x - mx)`, where both zero signs give exactly 1.0 — outputs
    /// cannot diverge.
    ///
    /// # Safety
    ///
    /// AVX2 must be available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn vmax(s: &[f32]) -> f32 {
        let n = s.len();
        let chunks = n / NR;
        let sp = s.as_ptr();
        let mut acc = _mm256_set1_ps(f32::NEG_INFINITY);
        for c in 0..chunks {
            acc = _mm256_max_ps(acc, _mm256_loadu_ps(sp.add(c * NR)));
        }
        let mut lanes = [0.0f32; NR];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (l, t) in (chunks * NR..n).enumerate() {
            lanes[l] = lanes[l].max(*sp.add(t));
        }
        reduce_max_lanes(&lanes)
    }

    /// Softmax normalise: `s[t] /= denom`. IEEE division is correctly
    /// rounded, so `vdivps` matches the scalar `/` bit-for-bit.
    ///
    /// # Safety
    ///
    /// AVX2 must be available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn div_all(s: &mut [f32], denom: f32) {
        let n = s.len();
        let chunks = n / NR;
        let dv = _mm256_set1_ps(denom);
        let sp = s.as_mut_ptr();
        for c in 0..chunks {
            let v = _mm256_loadu_ps(sp.add(c * NR));
            _mm256_storeu_ps(sp.add(c * NR), _mm256_div_ps(v, dv));
        }
        for t in chunks * NR..n {
            *sp.add(t) /= denom;
        }
    }

    /// Lane-shaped sum (LayerNorm mean pass).
    ///
    /// # Safety
    ///
    /// AVX2 must be available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sum(x: &[f32]) -> f32 {
        let n = x.len();
        let chunks = n / NR;
        let xp = x.as_ptr();
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            acc = _mm256_add_ps(acc, _mm256_loadu_ps(xp.add(c * NR)));
        }
        let mut lanes = [0.0f32; NR];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (l, t) in (chunks * NR..n).enumerate() {
            lanes[l] += *xp.add(t);
        }
        reduce_lanes(&lanes)
    }

    /// Lane-shaped squared-deviation sum (LayerNorm variance pass).
    ///
    /// # Safety
    ///
    /// AVX2 must be available.
    #[target_feature(enable = "avx2")]
    pub unsafe fn sqdev(x: &[f32], mu: f32) -> f32 {
        let n = x.len();
        let chunks = n / NR;
        let xp = x.as_ptr();
        let muv = _mm256_set1_ps(mu);
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let dv = _mm256_sub_ps(_mm256_loadu_ps(xp.add(c * NR)), muv);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(dv, dv));
        }
        let mut lanes = [0.0f32; NR];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        for (l, t) in (chunks * NR..n).enumerate() {
            let dv = *xp.add(t) - mu;
            lanes[l] += dv * dv;
        }
        reduce_lanes(&lanes)
    }

    /// LayerNorm normalise pass, elementwise:
    /// `out[t] = (row[t] - mu) * iv * scale[t] + bias[t]` with the scalar
    /// twin's rounding order (sub, two multiplies, one add).
    ///
    /// # Safety
    ///
    /// AVX2 must be available and all four slices must share one length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn norm_row(
        out: &mut [f32],
        row: &[f32],
        scale: &[f32],
        bias: &[f32],
        mu: f32,
        iv: f32,
    ) {
        let n = out.len();
        let chunks = n / NR;
        let muv = _mm256_set1_ps(mu);
        let ivv = _mm256_set1_ps(iv);
        let op = out.as_mut_ptr();
        let rp = row.as_ptr();
        let sp = scale.as_ptr();
        let bp = bias.as_ptr();
        for c in 0..chunks {
            let o = c * NR;
            let t = _mm256_sub_ps(_mm256_loadu_ps(rp.add(o)), muv);
            let t = _mm256_mul_ps(t, ivv);
            let t = _mm256_mul_ps(t, _mm256_loadu_ps(sp.add(o)));
            let t = _mm256_add_ps(t, _mm256_loadu_ps(bp.add(o)));
            _mm256_storeu_ps(op.add(o), t);
        }
        for t in chunks * NR..n {
            *op.add(t) = (*rp.add(t) - mu) * iv * *sp.add(t) + *bp.add(t);
        }
    }
}

/// Run the selected register tile for one panel:
/// `acc[r][j] += sum_p apack[r*KC + p] * panel[p*NR + j]`.
#[inline(always)]
fn run_tile(
    acc: &mut [[f32; NR]; MR],
    apack: &[f32; MR * KC],
    panel: &[f32],
    kcl: usize,
    mr: usize,
    isa: KernelIsa,
) {
    match isa {
        // SAFETY: `Avx2` is only selected after feature detection succeeded
        // (see `active_isa`), and the callers zero-fill `apack` rows
        // `mr..MR` so the full-height tile reads no stale values.
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => unsafe { avx2::tile(acc, apack, panel, kcl) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => tile_scalar(acc, apack, panel, kcl, mr),
        KernelIsa::Scalar => tile_scalar(acc, apack, panel, kcl, mr),
    }
}

/// The blocked compute over output rows `i0..i0 + rows` (`c` holds exactly
/// those rows). `set` overwrites `c` on the first `k` block instead of
/// accumulating; `fused` applies `pre += bias; act = gelu(pre)` once each
/// row's accumulation is complete.
fn gemm_rows(
    c: &mut [f32],
    a: &[f32],
    amode: AMode,
    packed: &[f32],
    i0: usize,
    rows: usize,
    m: usize,
    k: usize,
    n: usize,
    set: bool,
    isa: KernelIsa,
    mut fused: Option<(&mut [f32], &[f32])>,
) {
    let n_panels = div_ceil(n, NR);
    let kblocks = div_ceil(k, KC);
    let mut apack = [0.0f32; MR * KC];
    let mut ib = 0;
    while ib < rows {
        let mr = MR.min(rows - ib);
        for kb in 0..kblocks {
            let p0 = kb * KC;
            let kcl = KC.min(k - p0);
            // Pack the A micropanel for this row block x k block.
            for r in 0..mr {
                let gi = i0 + ib + r;
                match amode {
                    AMode::Rows => {
                        apack[r * KC..r * KC + kcl]
                            .copy_from_slice(&a[gi * k + p0..gi * k + p0 + kcl]);
                    }
                    AMode::Cols => {
                        for p in 0..kcl {
                            apack[r * KC + p] = a[(p0 + p) * m + gi];
                        }
                    }
                }
            }
            // Rows `mr..MR` may hold a previous block's values; zero them so
            // the full-height SIMD tile multiplies zeros into its discarded
            // rows (only the final ragged row block ever pays this).
            for r in mr..MR {
                apack[r * KC..r * KC + kcl].fill(0.0);
            }
            let first = kb == 0;
            let block_base = kb * KC * n_panels * NR;
            for jp in 0..n_panels {
                let j0 = jp * NR;
                let jn = NR.min(n - j0);
                let panel = &packed[block_base + jp * kcl * NR..block_base + (jp + 1) * kcl * NR];
                let mut acc = [[0.0f32; NR]; MR];
                run_tile(&mut acc, &apack, panel, kcl, mr, isa);
                for r in 0..mr {
                    let crow = &mut c[(ib + r) * n + j0..(ib + r) * n + j0 + jn];
                    if set && first {
                        crow.copy_from_slice(&acc[r][..jn]);
                    } else {
                        for j in 0..jn {
                            crow[j] += acc[r][j];
                        }
                    }
                }
            }
        }
        if let Some((act, bias)) = fused.as_mut() {
            for r in 0..mr {
                let crow = &mut c[(ib + r) * n..(ib + r) * n + n];
                let arow = &mut act[(ib + r) * n..(ib + r) * n + n];
                for j in 0..n {
                    let v = crow[j] + bias[j];
                    crow[j] = v;
                    arow[j] = gelu(v);
                }
            }
        }
        ib += MR;
    }
}

/// Scalar path for operands too small to amortise packing. Replays the
/// blocked path's exact per-element operation sequence (same `KC` blocks,
/// same ascending-`p` register sums, same write-back), so results are
/// bit-identical to [`gemm_rows`] — path choice can never change output.
fn gemm_small(
    c: &mut [f32],
    a: &[f32],
    amode: AMode,
    b: &[f32],
    bt: bool,
    m: usize,
    k: usize,
    n: usize,
    set: bool,
    mut fused: Option<(&mut [f32], &[f32])>,
) {
    let kblocks = div_ceil(k, KC);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            for kb in 0..kblocks {
                let p0 = kb * KC;
                let kcl = KC.min(k - p0);
                let mut acc = 0.0f32;
                for p in 0..kcl {
                    let av = match amode {
                        AMode::Rows => a[i * k + p0 + p],
                        AMode::Cols => a[(p0 + p) * m + i],
                    };
                    let bv = if bt { b[j * k + p0 + p] } else { b[(p0 + p) * n + j] };
                    acc += av * bv;
                }
                if set && kb == 0 {
                    crow[j] = acc;
                } else {
                    crow[j] += acc;
                }
            }
        }
        if let Some((act, bias)) = fused.as_mut() {
            for j in 0..n {
                let v = crow[j] + bias[j];
                crow[j] = v;
                act[i * n + j] = gelu(v);
            }
        }
    }
}

/// Blocked GEMM over a pre-packed `b`, row-parallel when worthwhile.
fn gemm_packed(
    c: &mut [f32],
    a: &[f32],
    amode: AMode,
    packed: &[f32],
    m: usize,
    k: usize,
    n: usize,
    set: bool,
    fused: Option<(&mut [f32], &[f32])>,
) {
    let isa = active_isa();
    let blocks = div_ceil(m, MR);
    if blocks < 2 || !parallel_ok(m, m * k * n) {
        gemm_rows(c, a, amode, packed, 0, m, m, k, n, set, isa, fused);
        return;
    }
    // Chunk in whole MR-row blocks, a few chunks per worker so the atomic
    // claim loop load-balances ragged finish times.
    let bpc = div_ceil(blocks, pool().workers() * 4).max(1);
    let n_chunks = div_ceil(blocks, bpc);
    if n_chunks < 2 {
        gemm_rows(c, a, amode, packed, 0, m, m, k, n, set, isa, fused);
        return;
    }
    let cptr = SendPtr(c.as_mut_ptr());
    let (act_ptr, bias): (Option<SendPtr>, Option<&[f32]>) = match fused {
        Some((act, bias)) => (Some(SendPtr(act.as_mut_ptr())), Some(bias)),
        None => (None, None),
    };
    run_chunks(n_chunks, &|ci: usize| {
        let i0 = ci * bpc * MR;
        let i1 = m.min(i0 + bpc * MR);
        let rows = i1 - i0;
        // SAFETY: chunks cover disjoint row ranges of `c` (and `act`), so
        // the per-chunk mutable slices never alias.
        let cc = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
        let fc = match (act_ptr, bias) {
            (Some(ap), Some(bs)) => Some((
                unsafe { std::slice::from_raw_parts_mut(ap.0.add(i0 * n), rows * n) },
                bs,
            )),
            _ => None,
        };
        gemm_rows(cc, a, amode, packed, i0, rows, m, k, n, set, isa, fc);
    });
}

/// Entry point for unpacked operands: small ops take the scalar path, the
/// rest pack `b` into per-thread reusable scratch and run blocked.
fn gemm(
    c: &mut [f32],
    a: &[f32],
    amode: AMode,
    b: &[f32],
    bt: bool,
    m: usize,
    k: usize,
    n: usize,
    set: bool,
    fused: Option<(&mut [f32], &[f32])>,
) {
    debug_assert_eq!(c.len(), m * n);
    if m * k * n < SMALL_GEMM_WORK {
        gemm_small(c, a, amode, b, bt, m, k, n, set, fused);
        return;
    }
    PACK_SCRATCH.with(|s| {
        let mut buf = s.borrow_mut();
        pack_b_into(&mut buf, b, k, n, bt);
        gemm_packed(c, a, amode, &buf, m, k, n, set, fused);
    });
}

// ---------------------------------------------------------------------------
// Matmul family (row-major; bit-identical across thread counts and paths)

/// c[m,n] += a[m,k] · b[k,n]
pub fn matmul_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    gemm(c, a, AMode::Rows, b, false, m, k, n, false, None);
}

/// c[m,n] = a[m,k] · b[k,n] — overwrite variant: no zeroing pass over `c`
/// (callers drop one full memory sweep per projection vs reset + acc).
pub fn matmul_set(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    gemm(c, a, AMode::Rows, b, false, m, k, n, true, None);
}

/// c[m,n] = a[m,k] · b[k,n]
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_set(&mut c, a, b, m, k, n);
    c
}

/// Fused MLP up-projection epilogue: `pre[m,n] = a·b + bias` and
/// `act = gelu(pre)` written in the same pass over the output tile, so the
/// pre-activation buffer is swept once instead of three times.
pub fn matmul_set_bias_gelu(
    pre: &mut [f32],
    act: &mut [f32],
    a: &[f32],
    b: &[f32],
    bias: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(act.len(), m * n);
    debug_assert_eq!(bias.len(), n);
    gemm(pre, a, AMode::Rows, b, false, m, k, n, true, Some((act, bias)));
}

/// c[m,n] += aᵀ · b where a is [k,m] and b is [k,n] (weight gradients).
pub fn matmul_at_b_acc(c: &mut [f32], a: &[f32], b: &[f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    gemm(c, a, AMode::Cols, b, false, m, k, n, false, None);
}

/// c[m,n] += a · bᵀ where a is [m,k] and b is [n,k] (input gradients).
pub fn matmul_a_bt_acc(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    gemm(c, a, AMode::Rows, b, true, m, k, n, false, None);
}

/// A `[k, n]` weight matrix pre-packed into the blocked panel layout, for
/// callers whose `b` operand is frozen across many GEMMs — decode sessions
/// pack each layer's weights once per snapshot and reuse them every token.
pub struct PackedB {
    data: Vec<f32>,
    k: usize,
    n: usize,
}

impl PackedB {
    pub fn pack(b: &[f32], k: usize, n: usize) -> PackedB {
        debug_assert_eq!(b.len(), k * n);
        let mut data = Vec::new();
        pack_b_into(&mut data, b, k, n, false);
        PackedB { data, k, n }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }
}

/// `c[m, n] = a[m, k] · b` against a pre-packed `b`: skips the pack pass,
/// same blocked arithmetic — results match [`matmul_set`] bit-for-bit.
pub fn matmul_set_packed(c: &mut [f32], a: &[f32], b: &PackedB, m: usize) {
    debug_assert_eq!(a.len(), m * b.k);
    debug_assert_eq!(c.len(), m * b.n);
    gemm_packed(c, a, AMode::Rows, &b.data, m, b.k, b.n, true, None);
}

/// [`matmul_set_bias_gelu`] against a pre-packed `b`.
pub fn matmul_set_bias_gelu_packed(
    pre: &mut [f32],
    act: &mut [f32],
    a: &[f32],
    b: &PackedB,
    bias: &[f32],
    m: usize,
) {
    debug_assert_eq!(a.len(), m * b.k);
    debug_assert_eq!(pre.len(), m * b.n);
    debug_assert_eq!(act.len(), m * b.n);
    debug_assert_eq!(bias.len(), b.n);
    gemm_packed(pre, a, AMode::Rows, &b.data, m, b.k, b.n, true, Some((act, bias)));
}

// ---------------------------------------------------------------------------
// Fused multi-B GEMM: one shared A micropanel streamed through several
// packed B operands (the q/k/v projection triple)

/// How many `B` operands the fused multi-`B` path carries (q, k, v).
pub const MULTI_B: usize = 3;

/// [`gemm_rows`] over [`MULTI_B`] outputs sharing one `a`: the A micropanel
/// is packed once per (row block x k block) and streamed through each
/// packed `b` in turn. Each output's per-element accumulation order is
/// exactly the single-`B` order, so results are bit-identical to separate
/// calls — only the (redundant) A-pack work is shared.
fn gemm_rows_multi(
    cs: &mut [&mut [f32]; MULTI_B],
    a: &[f32],
    amode: AMode,
    packs: &[&[f32]; MULTI_B],
    i0: usize,
    rows: usize,
    m: usize,
    k: usize,
    n: usize,
    set: bool,
    isa: KernelIsa,
) {
    let n_panels = div_ceil(n, NR);
    let kblocks = div_ceil(k, KC);
    let mut apack = [0.0f32; MR * KC];
    let mut ib = 0;
    while ib < rows {
        let mr = MR.min(rows - ib);
        for kb in 0..kblocks {
            let p0 = kb * KC;
            let kcl = KC.min(k - p0);
            for r in 0..mr {
                let gi = i0 + ib + r;
                match amode {
                    AMode::Rows => {
                        apack[r * KC..r * KC + kcl]
                            .copy_from_slice(&a[gi * k + p0..gi * k + p0 + kcl]);
                    }
                    AMode::Cols => {
                        for p in 0..kcl {
                            apack[r * KC + p] = a[(p0 + p) * m + gi];
                        }
                    }
                }
            }
            for r in mr..MR {
                apack[r * KC..r * KC + kcl].fill(0.0);
            }
            let first = kb == 0;
            let block_base = kb * KC * n_panels * NR;
            for (c, packed) in cs.iter_mut().zip(packs.iter()) {
                for jp in 0..n_panels {
                    let j0 = jp * NR;
                    let jn = NR.min(n - j0);
                    let panel =
                        &packed[block_base + jp * kcl * NR..block_base + (jp + 1) * kcl * NR];
                    let mut acc = [[0.0f32; NR]; MR];
                    run_tile(&mut acc, &apack, panel, kcl, mr, isa);
                    for r in 0..mr {
                        let crow = &mut c[(ib + r) * n + j0..(ib + r) * n + j0 + jn];
                        if set && first {
                            crow.copy_from_slice(&acc[r][..jn]);
                        } else {
                            for j in 0..jn {
                                crow[j] += acc[r][j];
                            }
                        }
                    }
                }
            }
        }
        ib += MR;
    }
}

/// Parallel driver for the multi-`B` path (mirrors [`gemm_packed`]).
fn gemm_packed_multi(
    cs: &mut [&mut [f32]; MULTI_B],
    a: &[f32],
    amode: AMode,
    packs: &[&[f32]; MULTI_B],
    m: usize,
    k: usize,
    n: usize,
    set: bool,
) {
    let isa = active_isa();
    let blocks = div_ceil(m, MR);
    if blocks < 2 || !parallel_ok(m, MULTI_B * m * k * n) {
        gemm_rows_multi(cs, a, amode, packs, 0, m, m, k, n, set, isa);
        return;
    }
    let bpc = div_ceil(blocks, pool().workers() * 4).max(1);
    let n_chunks = div_ceil(blocks, bpc);
    if n_chunks < 2 {
        gemm_rows_multi(cs, a, amode, packs, 0, m, m, k, n, set, isa);
        return;
    }
    let p0 = SendPtr(cs[0].as_mut_ptr());
    let p1 = SendPtr(cs[1].as_mut_ptr());
    let p2 = SendPtr(cs[2].as_mut_ptr());
    let ptrs = [p0, p1, p2];
    run_chunks(n_chunks, &|ci: usize| {
        let i0 = ci * bpc * MR;
        let i1 = m.min(i0 + bpc * MR);
        let rows = i1 - i0;
        // SAFETY: chunks cover disjoint row ranges of each output buffer,
        // so the per-chunk mutable slices never alias.
        let mut chunk: [&mut [f32]; MULTI_B] = [
            unsafe { std::slice::from_raw_parts_mut(ptrs[0].0.add(i0 * n), rows * n) },
            unsafe { std::slice::from_raw_parts_mut(ptrs[1].0.add(i0 * n), rows * n) },
            unsafe { std::slice::from_raw_parts_mut(ptrs[2].0.add(i0 * n), rows * n) },
        ];
        gemm_rows_multi(&mut chunk, a, amode, packs, i0, rows, m, k, n, set, isa);
    });
}

/// Entry for unpacked multi-`B` operands: small ops replay the scalar path
/// per output (bit-identical to single calls by construction); larger ops
/// pack all three `b` operands back-to-back into the per-thread scratch and
/// run the fused blocked path.
fn gemm_multi(
    cs: &mut [&mut [f32]; MULTI_B],
    a: &[f32],
    amode: AMode,
    bs: &[&[f32]; MULTI_B],
    bt: bool,
    m: usize,
    k: usize,
    n: usize,
    set: bool,
) {
    if m * k * n < SMALL_GEMM_WORK {
        for (c, b) in cs.iter_mut().zip(bs.iter()) {
            gemm_small(c, a, amode, b, bt, m, k, n, set, None);
        }
        return;
    }
    PACK_SCRATCH.with(|s| {
        let mut buf = s.borrow_mut();
        let section = k * div_ceil(n, NR) * NR;
        buf.clear();
        buf.resize(MULTI_B * section, 0.0);
        let (s0, rest) = buf.split_at_mut(section);
        let (s1, s2) = rest.split_at_mut(section);
        pack_b_panels(s0, bs[0], k, n, bt);
        pack_b_panels(s1, bs[1], k, n, bt);
        pack_b_panels(s2, bs[2], k, n, bt);
        let packs: [&[f32]; MULTI_B] = [&*s0, &*s1, &*s2];
        gemm_packed_multi(cs, a, amode, &packs, m, k, n, set);
    });
}

/// Fused q/k/v projection: `c_i = a · b_i` for [`MULTI_B`] same-shape `b`
/// operands sharing one `a` `[m, k]`. The A micropanel is packed once per
/// (row block x k block) and streamed through all three packed `b` panels,
/// cutting A-pack traffic to a third; results are bit-identical to three
/// separate [`matmul_set`] calls.
pub fn matmul_set_multi(
    mut cs: [&mut [f32]; MULTI_B],
    a: &[f32],
    bs: [&[f32]; MULTI_B],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    for (c, b) in cs.iter().zip(bs.iter()) {
        debug_assert_eq!(c.len(), m * n);
        debug_assert_eq!(b.len(), k * n);
    }
    gemm_multi(&mut cs, a, AMode::Rows, &bs, false, m, k, n, true);
}

/// `c_i += aᵀ · b_i` (`a` is `[k, m]`, each `b_i` `[k, n]`): the backward
/// counterpart of [`matmul_set_multi`] for the wq/wk/wv weight gradients.
/// Sharing matters most here — the transposed A-pack is a strided gather
/// (`a[p * m + i]`), the most expensive pack in the backward pass.
pub fn matmul_at_b_acc_multi(
    mut cs: [&mut [f32]; MULTI_B],
    a: &[f32],
    bs: [&[f32]; MULTI_B],
    k: usize,
    m: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), k * m);
    for (c, b) in cs.iter().zip(bs.iter()) {
        debug_assert_eq!(c.len(), m * n);
        debug_assert_eq!(b.len(), k * n);
    }
    gemm_multi(&mut cs, a, AMode::Cols, &bs, false, m, k, n, false);
}

/// [`matmul_set_multi`] against pre-packed weights (decode sessions hold
/// `PackedB` q/k/v panels). Like [`matmul_set_packed`], always runs the
/// blocked path — still bit-identical to the unpacked entry.
pub fn matmul_set_packed_multi(
    mut cs: [&mut [f32]; MULTI_B],
    a: &[f32],
    bs: [&PackedB; MULTI_B],
    m: usize,
) {
    let (k, n) = (bs[0].k, bs[0].n);
    debug_assert!(bs.iter().all(|b| b.k == k && b.n == n), "multi-B operands must share shape");
    debug_assert_eq!(a.len(), m * k);
    for c in cs.iter() {
        debug_assert_eq!(c.len(), m * n);
    }
    let packs: [&[f32]; MULTI_B] = [&bs[0].data, &bs[1].data, &bs[2].data];
    gemm_packed_multi(&mut cs, a, AMode::Rows, &packs, m, k, n, true);
}

// ---------------------------------------------------------------------------
// Lane-shaped vector primitives (attention + LayerNorm)
//
// Same playbook as the GEMM register tile: a scalar twin written in a fixed
// 8-lane ([`NR`]) partial-sum shape that the compiler autovectorizes, and an
// AVX2 twin that replays that exact per-lane operation order with separate
// multiply and add instructions — never `vfmadd` — so scalar ≡ SIMD stays
// bit-identical. Reductions always combine lanes in the same ascending
// order, and tail elements (`len % NR`) always land in lanes `0..rem` after
// the chunked body, on both paths.

/// The fixed lane-combine order every lane-shaped accumulator funnels
/// through: strictly ascending lanes. Shared by the scalar and AVX2 twins so
/// partial sums combine identically on every path.
#[inline(always)]
fn reduce_lanes(lanes: &[f32; NR]) -> f32 {
    let mut acc = lanes[0];
    for l in 1..NR {
        acc += lanes[l];
    }
    acc
}

/// Ascending-lane max combine. Max over distinct finite values is
/// order-insensitive, but the fixed order keeps the contract uniform.
#[inline(always)]
fn reduce_max_lanes(lanes: &[f32; NR]) -> f32 {
    let mut m = lanes[0];
    for l in 1..NR {
        m = m.max(lanes[l]);
    }
    m
}

/// Scalar twin of the lane dot product: 8 independent lane sums over the
/// chunked body, tail into lanes `0..rem`, fixed ascending reduce.
#[inline(always)]
fn dot_lanes_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / NR;
    let mut lanes = [0.0f32; NR];
    for c in 0..chunks {
        let ar = &a[c * NR..c * NR + NR];
        let br = &b[c * NR..c * NR + NR];
        for l in 0..NR {
            lanes[l] += ar[l] * br[l];
        }
    }
    for (l, t) in (chunks * NR..n).enumerate() {
        lanes[l] += a[t] * b[t];
    }
    reduce_lanes(&lanes)
}

/// `dot(a, b)` in the fixed lane shape, dispatched on `isa`.
#[inline(always)]
fn dot_lanes(a: &[f32], b: &[f32], isa: KernelIsa) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        // SAFETY: `Avx2` is only selected after feature detection succeeded
        // (see `active_isa`).
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => dot_lanes_scalar(a, b),
        KernelIsa::Scalar => dot_lanes_scalar(a, b),
    }
}

/// Scalar twin of `out[t] += a * x[t]` — elementwise (one rounded multiply,
/// one rounded add per element), so lane width cannot reorder anything.
#[inline(always)]
fn axpy_scalar(out: &mut [f32], x: &[f32], a: f32) {
    for (o, &xv) in out.iter_mut().zip(x.iter()) {
        *o += a * xv;
    }
}

#[inline(always)]
fn axpy(out: &mut [f32], x: &[f32], a: f32, isa: KernelIsa) {
    debug_assert_eq!(out.len(), x.len());
    match isa {
        // SAFETY: selected only after feature detection (see `active_isa`).
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => unsafe { avx2::axpy(out, x, a) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => axpy_scalar(out, x, a),
        KernelIsa::Scalar => axpy_scalar(out, x, a),
    }
}

/// Scalar twin of the lane max (softmax stabiliser).
#[inline(always)]
fn max_lanes_scalar(s: &[f32]) -> f32 {
    let n = s.len();
    let chunks = n / NR;
    let mut lanes = [f32::NEG_INFINITY; NR];
    for c in 0..chunks {
        let r = &s[c * NR..c * NR + NR];
        for l in 0..NR {
            lanes[l] = lanes[l].max(r[l]);
        }
    }
    for (l, t) in (chunks * NR..n).enumerate() {
        lanes[l] = lanes[l].max(s[t]);
    }
    reduce_max_lanes(&lanes)
}

#[inline(always)]
fn max_lanes(s: &[f32], isa: KernelIsa) -> f32 {
    match isa {
        // SAFETY: selected only after feature detection (see `active_isa`).
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => unsafe { avx2::vmax(s) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => max_lanes_scalar(s),
        KernelIsa::Scalar => max_lanes_scalar(s),
    }
}

/// Scalar twin of the softmax normalise pass: one correctly-rounded divide
/// per element, so lane width cannot change it.
#[inline(always)]
fn div_all_scalar(s: &mut [f32], denom: f32) {
    for v in s.iter_mut() {
        *v /= denom;
    }
}

#[inline(always)]
fn div_all(s: &mut [f32], denom: f32, isa: KernelIsa) {
    match isa {
        // SAFETY: selected only after feature detection (see `active_isa`).
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => unsafe { avx2::div_all(s, denom) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => div_all_scalar(s, denom),
        KernelIsa::Scalar => div_all_scalar(s, denom),
    }
}

/// Softmax exp pass: `s[j] = exp(s[j] - mx)` in place, returning the
/// denominator accumulated in the fixed lane shape. The `exp` itself stays
/// scalar libm on *every* path — there is no bit-exact vector exp to pair
/// with it, so both register tiles share this one function and the
/// scalar ≡ SIMD contract holds trivially; the surrounding dot, max,
/// normalise, and context passes are where the lane width pays.
#[inline(always)]
fn exp_denom_lanes(s: &mut [f32], mx: f32) -> f32 {
    let n = s.len();
    let chunks = n / NR;
    let mut lanes = [0.0f32; NR];
    for c in 0..chunks {
        let r = &mut s[c * NR..c * NR + NR];
        for l in 0..NR {
            let e = (r[l] - mx).exp();
            r[l] = e;
            lanes[l] += e;
        }
    }
    for (l, t) in (chunks * NR..n).enumerate() {
        let e = (s[t] - mx).exp();
        s[t] = e;
        lanes[l] += e;
    }
    reduce_lanes(&lanes)
}

/// Scalar twin of the LayerNorm row sum (mean pass).
#[inline(always)]
fn sum_lanes_scalar(x: &[f32]) -> f32 {
    let n = x.len();
    let chunks = n / NR;
    let mut lanes = [0.0f32; NR];
    for c in 0..chunks {
        let r = &x[c * NR..c * NR + NR];
        for l in 0..NR {
            lanes[l] += r[l];
        }
    }
    for (l, t) in (chunks * NR..n).enumerate() {
        lanes[l] += x[t];
    }
    reduce_lanes(&lanes)
}

#[inline(always)]
fn sum_lanes(x: &[f32], isa: KernelIsa) -> f32 {
    match isa {
        // SAFETY: selected only after feature detection (see `active_isa`).
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => unsafe { avx2::sum(x) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => sum_lanes_scalar(x),
        KernelIsa::Scalar => sum_lanes_scalar(x),
    }
}

/// Scalar twin of the LayerNorm squared-deviation sum (variance pass).
#[inline(always)]
fn sqdev_lanes_scalar(x: &[f32], mu: f32) -> f32 {
    let n = x.len();
    let chunks = n / NR;
    let mut lanes = [0.0f32; NR];
    for c in 0..chunks {
        let r = &x[c * NR..c * NR + NR];
        for l in 0..NR {
            let dv = r[l] - mu;
            lanes[l] += dv * dv;
        }
    }
    for (l, t) in (chunks * NR..n).enumerate() {
        let dv = x[t] - mu;
        lanes[l] += dv * dv;
    }
    reduce_lanes(&lanes)
}

#[inline(always)]
fn sqdev_lanes(x: &[f32], mu: f32, isa: KernelIsa) -> f32 {
    match isa {
        // SAFETY: selected only after feature detection (see `active_isa`).
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => unsafe { avx2::sqdev(x, mu) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => sqdev_lanes_scalar(x, mu),
        KernelIsa::Scalar => sqdev_lanes_scalar(x, mu),
    }
}

/// Scalar twin of the LayerNorm normalise pass:
/// `out[j] = (row[j] - mu) * iv * scale[j] + bias[j]`, elementwise.
#[inline(always)]
fn ln_norm_row_scalar(out: &mut [f32], row: &[f32], scale: &[f32], bias: &[f32], mu: f32, iv: f32) {
    for j in 0..out.len() {
        out[j] = (row[j] - mu) * iv * scale[j] + bias[j];
    }
}

#[inline(always)]
fn ln_norm_row(
    out: &mut [f32],
    row: &[f32],
    scale: &[f32],
    bias: &[f32],
    mu: f32,
    iv: f32,
    isa: KernelIsa,
) {
    debug_assert!(row.len() == out.len() && scale.len() == out.len() && bias.len() == out.len());
    match isa {
        // SAFETY: selected only after feature detection (see `active_isa`).
        #[cfg(target_arch = "x86_64")]
        KernelIsa::Avx2 => unsafe { avx2::norm_row(out, row, scale, bias, mu, iv) },
        #[cfg(not(target_arch = "x86_64"))]
        KernelIsa::Avx2 => ln_norm_row_scalar(out, row, scale, bias, mu, iv),
        KernelIsa::Scalar => ln_norm_row_scalar(out, row, scale, bias, mu, iv),
    }
}

// ---------------------------------------------------------------------------
// GELU (tanh approximation — jax.nn.gelu's default) and LayerNorm

const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi)
const GELU_K: f32 = 0.044_715;

pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + GELU_K * x * x * x)).tanh())
}

pub fn gelu_grad(x: f32) -> f32 {
    let u = GELU_C * (x + GELU_K * x * x * x);
    let th = u.tanh();
    let sech2 = 1.0 - th * th;
    0.5 * (1.0 + th) + 0.5 * x * sech2 * GELU_C * (1.0 + 3.0 * GELU_K * x * x)
}

pub const LN_EPS: f32 = 1e-5;

/// Re-zero `buf` to exactly `n` elements, keeping its allocation. The
/// workspace idiom: `clear` drops the length without touching capacity, so
/// after warm-up `resize` never reallocates.
pub fn reset(buf: &mut Vec<f32>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

/// LayerNorm over `rows` rows of width `d`; returns `(y, mean, inv_std)`.
/// The training path keeps mean/inv for its backward; decode ignores them.
pub fn layernorm_stats(
    x: &[f32],
    scale: &[f32],
    bias: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (mut y, mut mean, mut inv) = (Vec::new(), Vec::new(), Vec::new());
    layernorm_stats_into(x, scale, bias, rows, d, &mut y, &mut mean, &mut inv);
    (y, mean, inv)
}

/// [`layernorm_stats`] writing into caller-owned buffers (resized here),
/// so the train workspace reuses its allocations every step. The mean,
/// variance, and normalise passes run in the fixed lane shape dispatched
/// across the scalar/AVX2 twins (see the lane primitives above), so results
/// are bit-identical across ISAs like every other kernel in this module.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_stats_into(
    x: &[f32],
    scale: &[f32],
    bias: &[f32],
    rows: usize,
    d: usize,
    y: &mut Vec<f32>,
    mean: &mut Vec<f32>,
    inv: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), rows * d);
    // Every element below is overwritten, so plain resizes suffice (no
    // zero-fill sweep).
    y.resize(rows * d, 0.0);
    inv.resize(rows, 0.0);
    mean.resize(rows, 0.0);
    let isa = active_isa();
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let mu = sum_lanes(row, isa) / d as f32;
        let var = sqdev_lanes(row, mu, isa) / d as f32;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        mean[r] = mu;
        inv[r] = iv;
        ln_norm_row(&mut y[r * d..(r + 1) * d], row, scale, bias, mu, iv, isa);
    }
}

/// LayerNorm returning only the normalised output (the decode hot path).
pub fn layernorm_rows(x: &[f32], scale: &[f32], bias: &[f32], rows: usize, d: usize) -> Vec<f32> {
    layernorm_stats(x, scale, bias, rows, d).0
}

// ---------------------------------------------------------------------------
// Causal multi-head attention (full window + incremental decode step)
//
// Head kernels are built from the lane-shaped primitives above and are
// dispatched as (batch row × head) work units: each unit owns its head's
// `probs` block and the `col..col + hd` column stripe of its batch row's
// output/gradient blocks, so units never alias and the SendPtr safety
// argument from the GEMM path carries over. Per-unit softmax scratch lives
// in a reusable per-thread buffer — steady-state decode performs zero
// attention allocations.

/// Reusable per-thread attention scratch (softmax scores / dprobs rows):
/// grown once per worker thread and reused across heads, layers, steps, and
/// sessions. Each head kernel resizes it and overwrites every element it
/// reads, so results never depend on which thread (or prior unit) last used
/// the buffer.
thread_local! {
    static ATTN_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Drive `run_unit` over `0..units` (batch row × head) work units: serially
/// below the parallel threshold, otherwise grouped into counter-claimed
/// chunks (a few per worker, like the GEMM row blocks). Each call receives
/// the running thread's reusable scratch buffer. Units are self-contained,
/// so serial and any chunk partition visit identical per-unit arithmetic —
/// results are bit-identical regardless of grain or thread count.
fn head_parallel(units: usize, work: usize, run_unit: &(dyn Fn(usize, &mut Vec<f32>) + Sync)) {
    if !parallel_ok(units, work) {
        ATTN_SCRATCH.with(|sc| {
            let mut buf = sc.borrow_mut();
            for u in 0..units {
                run_unit(u, &mut buf);
            }
        });
        return;
    }
    let upc = div_ceil(units, pool().workers() * 4).max(1);
    let n_chunks = div_ceil(units, upc);
    run_chunks(n_chunks, &|ci: usize| {
        ATTN_SCRATCH.with(|sc| {
            let mut buf = sc.borrow_mut();
            for u in ci * upc..units.min((ci + 1) * upc) {
                run_unit(u, &mut buf);
            }
        });
    });
}

/// Causal attention over a full `[b, s]` window. `q`/`k`/`v` are `[b, s, d]`
/// with per-head column blocks; fully overwrites `probs` `[b, h, s, s]`
/// (upper triangle zeroed) and `ctx` `[b, s, d]` — callers need not zero
/// either. Parallel over (batch row × head) units, so even a single-row
/// decode batch fans out across heads.
pub fn attention_forward(
    b: usize,
    s: usize,
    h: usize,
    hd: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &mut [f32],
    ctx: &mut [f32],
) {
    let d = h * hd;
    debug_assert_eq!(q.len(), b * s * d);
    debug_assert_eq!(k.len(), b * s * d);
    debug_assert_eq!(v.len(), b * s * d);
    debug_assert_eq!(probs.len(), b * h * s * s);
    debug_assert_eq!(ctx.len(), b * s * d);
    let isa = active_isa();
    let pp = SendPtr(probs.as_mut_ptr());
    let cp = SendPtr(ctx.as_mut_ptr());
    let run_unit = |u: usize, scores: &mut Vec<f32>| {
        let (bi, hh) = (u / h, u % h);
        // SAFETY: unit (bi, hh) writes only its own `[s, s]` probs block and
        // the `col..col + hd` column stripe of batch row `bi`'s ctx block;
        // both are disjoint across units.
        let probs_head =
            unsafe { std::slice::from_raw_parts_mut(pp.0.add((bi * h + hh) * s * s), s * s) };
        let ctx_row = SendPtr(unsafe { cp.0.add(bi * s * d) });
        attention_forward_head(
            s,
            d,
            hd,
            hh * hd,
            &q[bi * s * d..(bi + 1) * s * d],
            &k[bi * s * d..(bi + 1) * s * d],
            &v[bi * s * d..(bi + 1) * s * d],
            probs_head,
            ctx_row,
            scores,
            isa,
        );
    };
    head_parallel(b * h, b * h * s * s * hd, &run_unit);
}

/// One (batch row, head) unit of full-window causal attention: reads the
/// `col..col + hd` column stripe of the row-local `[s, d]` `q`/`k`/`v`,
/// writes the head's `[s, s]` probs block and its ctx column stripe (via
/// the batch row's base pointer — see the caller's SAFETY argument).
fn attention_forward_head(
    s: usize,
    d: usize,
    hd: usize,
    col: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &mut [f32],
    ctx: SendPtr,
    scores: &mut Vec<f32>,
    isa: KernelIsa,
) {
    let scale = 1.0 / (hd as f32).sqrt();
    for i in 0..s {
        let qrow = &q[i * d + col..i * d + col + hd];
        scores.resize(i + 1, 0.0);
        for j in 0..=i {
            scores[j] = dot_lanes(qrow, &k[j * d + col..j * d + col + hd], isa) * scale;
        }
        let mx = max_lanes(scores, isa);
        let denom = exp_denom_lanes(scores, mx);
        div_all(scores, denom, isa);
        let prow = &mut probs[i * s..(i + 1) * s];
        prow[..=i].copy_from_slice(scores);
        prow[i + 1..].fill(0.0);
        // SAFETY: see `attention_forward` — this unit owns this stripe.
        let crow = unsafe { std::slice::from_raw_parts_mut(ctx.0.add(i * d + col), hd) };
        crow.fill(0.0);
        for j in 0..=i {
            axpy(crow, &v[j * d + col..j * d + col + hd], scores[j], isa);
        }
    }
}

/// Backward of [`attention_forward`]: given `dctx` `[b, s, d]` and the
/// forward's `probs`/`q`/`k`/`v`, accumulates into `dq`/`dk`/`dv` (zeroed
/// by the caller — a unit's gradients span many positions, so the forward's
/// overwrite trick does not apply here). Parallel over (batch row × head)
/// units.
pub fn attention_backward(
    b: usize,
    s: usize,
    h: usize,
    hd: usize,
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dctx: &[f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    let d = h * hd;
    debug_assert_eq!(probs.len(), b * h * s * s);
    debug_assert_eq!(dctx.len(), b * s * d);
    debug_assert_eq!(dq.len(), b * s * d);
    debug_assert_eq!(dk.len(), b * s * d);
    debug_assert_eq!(dv.len(), b * s * d);
    let isa = active_isa();
    let qp = SendPtr(dq.as_mut_ptr());
    let kp = SendPtr(dk.as_mut_ptr());
    let vp = SendPtr(dv.as_mut_ptr());
    let run_unit = |u: usize, dprobs: &mut Vec<f32>| {
        let (bi, hh) = (u / h, u % h);
        let row0 = bi * s * d;
        // SAFETY: unit (bi, hh) accumulates only into the `col..col + hd`
        // column stripes of batch row `bi`'s dq/dk/dv blocks; disjoint
        // across units.
        let dqr = SendPtr(unsafe { qp.0.add(row0) });
        let dkr = SendPtr(unsafe { kp.0.add(row0) });
        let dvr = SendPtr(unsafe { vp.0.add(row0) });
        attention_backward_head(
            s,
            d,
            hd,
            hh * hd,
            &probs[(bi * h + hh) * s * s..(bi * h + hh + 1) * s * s],
            &q[row0..row0 + s * d],
            &k[row0..row0 + s * d],
            &v[row0..row0 + s * d],
            &dctx[row0..row0 + s * d],
            dqr,
            dkr,
            dvr,
            dprobs,
            isa,
        );
    };
    head_parallel(b * h, 2 * b * h * s * s * hd, &run_unit);
}

/// One (batch row, head) unit of attention backward (see
/// [`attention_backward`]). The `dscore` loop is branch-free: a zero
/// `dscore` contributes exact zeros, and dropping the old
/// `if dscore == 0.0 { continue }` skip keeps the inner loops in the same
/// multiply-add shape as the forward so they run on the lane primitives.
fn attention_backward_head(
    s: usize,
    d: usize,
    hd: usize,
    col: usize,
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dctx: &[f32],
    dq: SendPtr,
    dk: SendPtr,
    dv: SendPtr,
    dprobs: &mut Vec<f32>,
    isa: KernelIsa,
) {
    let scale = 1.0 / (hd as f32).sqrt();
    for i in 0..s {
        let prow = &probs[i * s..i * s + i + 1];
        let dcrow = &dctx[i * d + col..i * d + col + hd];
        dprobs.resize(i + 1, 0.0);
        // dprobs, the probs-weighted row dot, and dv.
        let mut rd_lanes = [0.0f32; NR];
        for j in 0..=i {
            let pj = prow[j];
            let a = dot_lanes(dcrow, &v[j * d + col..j * d + col + hd], isa);
            dprobs[j] = a;
            rd_lanes[j % NR] += a * pj;
            // SAFETY: see `attention_backward` — this unit owns this stripe.
            let dvrow = unsafe { std::slice::from_raw_parts_mut(dv.0.add(j * d + col), hd) };
            axpy(dvrow, dcrow, pj, isa);
        }
        let rowdot = reduce_lanes(&rd_lanes);
        // dscores -> dq, dk.
        let qrow = &q[i * d + col..i * d + col + hd];
        // SAFETY: as above.
        let dqrow = unsafe { std::slice::from_raw_parts_mut(dq.0.add(i * d + col), hd) };
        for j in 0..=i {
            let dscore = prow[j] * (dprobs[j] - rowdot) * scale;
            axpy(dqrow, &k[j * d + col..j * d + col + hd], dscore, isa);
            // SAFETY: as above.
            let dkrow = unsafe { std::slice::from_raw_parts_mut(dk.0.add(j * d + col), hd) };
            axpy(dkrow, qrow, dscore, isa);
        }
    }
}

/// One incremental decode step of causal attention: each row's single query
/// at position `pos` attends over its `pos + 1` cached keys. `q` is
/// `[rows, d]`; `kcache`/`vcache` are `[rows, cap, d]`; fully overwrites
/// `ctx` `[rows, d]` — callers need not zero it. Parallel over (row × head)
/// units, so small decode batches still fan out.
pub fn attention_decode_step(
    rows: usize,
    cap: usize,
    pos: usize,
    h: usize,
    hd: usize,
    q: &[f32],
    kcache: &[f32],
    vcache: &[f32],
    ctx: &mut [f32],
) {
    let d = h * hd;
    debug_assert!(pos < cap);
    debug_assert_eq!(q.len(), rows * d);
    debug_assert!(kcache.len() >= rows * cap * d);
    debug_assert!(vcache.len() >= rows * cap * d);
    debug_assert_eq!(ctx.len(), rows * d);
    let isa = active_isa();
    let cp = SendPtr(ctx.as_mut_ptr());
    let run_unit = |u: usize, scores: &mut Vec<f32>| {
        let (r, hh) = (u / h, u % h);
        // SAFETY: unit (r, hh) writes only the `col..col + hd` column stripe
        // of ctx row `r`; disjoint across units.
        let ctx_row = SendPtr(unsafe { cp.0.add(r * d) });
        attention_decode_head(
            pos,
            d,
            hd,
            hh * hd,
            &q[r * d..(r + 1) * d],
            &kcache[r * cap * d..(r + 1) * cap * d],
            &vcache[r * cap * d..(r + 1) * cap * d],
            ctx_row,
            scores,
            isa,
        );
    };
    head_parallel(rows * h, rows * (pos + 1) * d, &run_unit);
}

/// One (row, head) unit of decode attention (`q` `[d]`, caches `[cap, d]`).
/// Replays [`attention_forward_head`]'s per-lane arithmetic at position
/// `pos` exactly, so session logits match full-forward decode bit-for-bit.
fn attention_decode_head(
    pos: usize,
    d: usize,
    hd: usize,
    col: usize,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    ctx: SendPtr,
    scores: &mut Vec<f32>,
    isa: KernelIsa,
) {
    let scale = 1.0 / (hd as f32).sqrt();
    let qrow = &q[col..col + hd];
    scores.resize(pos + 1, 0.0);
    for j in 0..=pos {
        scores[j] = dot_lanes(qrow, &kc[j * d + col..j * d + col + hd], isa) * scale;
    }
    let mx = max_lanes(scores, isa);
    let denom = exp_denom_lanes(scores, mx);
    div_all(scores, denom, isa);
    // SAFETY: see `attention_decode_step` — this unit owns this stripe.
    let crow = unsafe { std::slice::from_raw_parts_mut(ctx.0.add(col), hd) };
    crow.fill(0.0);
    for j in 0..=pos {
        axpy(crow, &vc[j * d + col..j * d + col + hd], scores[j], isa);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Serialises tests that toggle or depend on the process-global
    /// `force_serial` flag (unit tests in this binary run concurrently).
    static SERIAL_GUARD: Mutex<()> = Mutex::new(());

    fn serial_guard() -> std::sync::MutexGuard<'static, ()> {
        SERIAL_GUARD.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    /// Textbook triple-loop reference.
    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a[i * k + p] as f64 * b[p * n + j] as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn pool_runs_borrowed_jobs_to_completion() {
        let mut out = vec![0u32; 64];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, chunk) in out.chunks_mut(8).enumerate() {
                jobs.push(Box::new(move || {
                    for (j, c) in chunk.iter_mut().enumerate() {
                        *c = (i * 8 + j) as u32;
                    }
                }));
            }
            pool().run(jobs);
        }
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn pool_propagates_job_panics() {
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("boom")),
                Box::new(|| {}),
            ];
            pool().run(jobs);
        }));
        // Single-worker pools run inline and propagate directly; multi-worker
        // pools re-panic from the latch. Either way the caller sees a panic.
        assert!(res.is_err());
    }

    /// Satellite regression: with >= 2 workers, N queued jobs must be *in
    /// flight simultaneously* — the old channel path blocked every worker on
    /// the shared receiver mutex during `recv`, serialising hand-offs.
    #[test]
    fn pool_jobs_make_progress_concurrently() {
        let _g = serial_guard();
        set_force_serial(false);
        if pool().workers() < 2 {
            return; // nothing to prove on a serial pool
        }
        let arrived = AtomicUsize::new(0);
        let t0 = std::time::Instant::now();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
        for _ in 0..2 {
            jobs.push(Box::new(|| {
                arrived.fetch_add(1, Ordering::SeqCst);
                // Each job waits (bounded) for the other: only concurrent
                // execution lets both exit.
                while arrived.load(Ordering::SeqCst) < 2 {
                    assert!(
                        t0.elapsed() < std::time::Duration::from_secs(30),
                        "queued jobs never ran concurrently"
                    );
                    std::thread::yield_now();
                }
            }));
        }
        pool().run(jobs);
        assert_eq!(arrived.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn run_chunks_covers_every_index_exactly_once() {
        let counts: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        run_chunks(97, &|i: usize| {
            counts[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::SeqCst), 1, "chunk {i} ran a wrong number of times");
        }
    }

    #[test]
    fn run_chunks_propagates_panics() {
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            run_chunks(8, &|i: usize| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err());
    }

    #[test]
    fn matmul_matches_naive_and_is_thread_invariant() {
        let _g = serial_guard();
        let mut rng = Pcg64::from_seed(1);
        // Large enough to cross both the small-GEMM and parallel thresholds
        // on multicore hosts, with ragged tails in every dimension.
        let (m, k, n) = (97, 67, 51);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        set_force_serial(false);
        let c = matmul(&a, &b, m, k, n);
        let reference = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&reference) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        set_force_serial(true);
        let c_serial = matmul(&a, &b, m, k, n);
        set_force_serial(false);
        assert_eq!(c, c_serial, "threaded matmul must be bit-identical to serial");
    }

    #[test]
    fn matmul_set_overwrites_garbage_and_matches_acc_from_zero() {
        let mut rng = Pcg64::from_seed(7);
        let (m, k, n) = (33, 40, 21);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut c_set = vec![f32::NAN; m * n]; // must be fully overwritten
        matmul_set(&mut c_set, &a, &b, m, k, n);
        let mut c_acc = vec![0.0f32; m * n];
        matmul_acc(&mut c_acc, &a, &b, m, k, n);
        assert_eq!(c_set, c_acc, "set variant must equal acc-from-zero bit-for-bit");
    }

    #[test]
    fn fused_bias_gelu_matches_unfused() {
        let mut rng = Pcg64::from_seed(8);
        let (m, k, n) = (26, 35, 29);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bias = randv(&mut rng, n);
        let mut pre = vec![0.0f32; m * n];
        let mut act = vec![0.0f32; m * n];
        matmul_set_bias_gelu(&mut pre, &mut act, &a, &b, &bias, m, k, n);

        let mut expect_pre = matmul(&a, &b, m, k, n);
        for r in 0..m {
            for j in 0..n {
                expect_pre[r * n + j] += bias[j];
            }
        }
        let expect_act: Vec<f32> = expect_pre.iter().map(|&z| gelu(z)).collect();
        assert_eq!(pre, expect_pre, "fused pre-activation diverged");
        assert_eq!(act, expect_act, "fused activation diverged");
    }

    #[test]
    fn packed_matmul_matches_unpacked_bitwise() {
        let mut rng = Pcg64::from_seed(9);
        // One shape under the small-GEMM threshold, one over it: the packed
        // entry always runs blocked, and must still match both.
        for (m, k, n) in [(3usize, 19usize, 11usize), (70, 64, 50)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            let unpacked = matmul(&a, &b, m, k, n);
            let packed = PackedB::pack(&b, k, n);
            assert_eq!(packed.k(), k);
            assert_eq!(packed.n(), n);
            let mut c = vec![f32::NAN; m * n];
            matmul_set_packed(&mut c, &a, &packed, m);
            assert_eq!(c, unpacked, "packed path diverged at {m}x{k}x{n}");
        }
    }

    #[test]
    fn at_b_and_a_bt_match_transposed_naive() {
        let mut rng = Pcg64::from_seed(2);
        let (m, k, n) = (40, 96, 32);
        // c[m,n] += aᵀ·b with a: [k,m].
        let a = randv(&mut rng, k * m);
        let b = randv(&mut rng, k * n);
        let mut c = vec![0.0f32; m * n];
        matmul_at_b_acc(&mut c, &a, &b, k, m, n);
        let mut at = vec![0.0f32; m * k];
        for p in 0..k {
            for i in 0..m {
                at[i * k + p] = a[p * m + i];
            }
        }
        let reference = naive_matmul(&at, &b, m, k, n);
        for (x, y) in c.iter().zip(&reference) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }

        // c[m,n] += a·bᵀ with b: [n,k].
        let a2 = randv(&mut rng, m * k);
        let b2 = randv(&mut rng, n * k);
        let mut c2 = vec![0.0f32; m * n];
        matmul_a_bt_acc(&mut c2, &a2, &b2, m, k, n);
        let mut b2t = vec![0.0f32; k * n];
        for j in 0..n {
            for p in 0..k {
                b2t[p * n + j] = b2[j * k + p];
            }
        }
        let reference2 = naive_matmul(&a2, &b2t, m, k, n);
        for (x, y) in c2.iter().zip(&reference2) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn decode_attention_matches_full_window_last_position() {
        let mut rng = Pcg64::from_seed(3);
        let (b, s, h, hd) = (3, 6, 2, 4);
        let d = h * hd;
        let q = randv(&mut rng, b * s * d);
        let k = randv(&mut rng, b * s * d);
        let v = randv(&mut rng, b * s * d);
        let mut probs = vec![0.0f32; b * h * s * s];
        let mut ctx = vec![0.0f32; b * s * d];
        attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);

        // Same data laid out as decode caches [rows, cap, d]; query = last pos.
        let pos = s - 1;
        let mut qlast = vec![0.0f32; b * d];
        for r in 0..b {
            qlast[r * d..(r + 1) * d].copy_from_slice(&q[(r * s + pos) * d..(r * s + pos + 1) * d]);
        }
        let mut ctx_step = vec![0.0f32; b * d];
        attention_decode_step(b, s, pos, h, hd, &qlast, &k, &v, &mut ctx_step);
        for r in 0..b {
            let full = &ctx[(r * s + pos) * d..(r * s + pos + 1) * d];
            let step = &ctx_step[r * d..(r + 1) * d];
            assert_eq!(full, step, "row {r}: decode-step attention diverged");
        }
    }

    #[test]
    fn simd_and_scalar_tiles_bit_identical() {
        let _g = serial_guard();
        if !simd_available() {
            eprintln!("skipping: no AVX2 on this host");
            return;
        }
        let mut rng = Pcg64::from_seed(11);
        // Ragged in every dimension, over the small-GEMM threshold.
        let (m, k, n) = (37, 300, 23);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bias = randv(&mut rng, n);
        let mut results: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = Vec::new();
        for isa in [KernelIsa::Scalar, KernelIsa::Avx2] {
            set_kernel_override(Some(isa));
            let c = matmul(&a, &b, m, k, n);
            let mut pre = vec![f32::NAN; m * n];
            let mut act = vec![f32::NAN; m * n];
            matmul_set_bias_gelu(&mut pre, &mut act, &a, &b, &bias, m, k, n);
            results.push((c, pre, act));
        }
        set_kernel_override(None);
        assert_eq!(results[0].0, results[1].0, "scalar vs SIMD matmul diverged");
        assert_eq!(results[0].1, results[1].1, "scalar vs SIMD fused pre diverged");
        assert_eq!(results[0].2, results[1].2, "scalar vs SIMD fused act diverged");
    }

    #[test]
    fn multi_b_matches_three_single_calls_bitwise() {
        let _g = serial_guard();
        let mut rng = Pcg64::from_seed(12);
        // One shape under the small-GEMM threshold, one blocked + ragged.
        for (m, k, n) in [(5usize, 9usize, 7usize), (37, 300, 23)] {
            let a = randv(&mut rng, m * k);
            let bs: Vec<Vec<f32>> = (0..MULTI_B).map(|_| randv(&mut rng, k * n)).collect();

            // matmul_set_multi vs three matmul_set calls (NaN-initialised:
            // the set path must fully overwrite).
            let mut single: Vec<Vec<f32>> = (0..MULTI_B).map(|_| vec![f32::NAN; m * n]).collect();
            for (c, b) in single.iter_mut().zip(bs.iter()) {
                matmul_set(c, &a, b, m, k, n);
            }
            let mut multi: Vec<Vec<f32>> = (0..MULTI_B).map(|_| vec![f32::NAN; m * n]).collect();
            {
                let (c0, rest) = multi.split_first_mut().unwrap();
                let (c1, rest) = rest.split_first_mut().unwrap();
                let c2 = &mut rest[0];
                matmul_set_multi(
                    [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                    &a,
                    [&bs[0], &bs[1], &bs[2]],
                    m,
                    k,
                    n,
                );
            }
            assert_eq!(single, multi, "matmul_set_multi diverged at {m}x{k}x{n}");

            // matmul_at_b_acc_multi vs three singles, from a seeded (nonzero)
            // accumulator.
            let at = randv(&mut rng, k * m);
            let seed: Vec<Vec<f32>> = (0..MULTI_B).map(|_| randv(&mut rng, m * n)).collect();
            let mut single_acc = seed.clone();
            for (c, b) in single_acc.iter_mut().zip(bs.iter()) {
                matmul_at_b_acc(c, &at, b, k, m, n);
            }
            let mut multi_acc = seed.clone();
            {
                let (c0, rest) = multi_acc.split_first_mut().unwrap();
                let (c1, rest) = rest.split_first_mut().unwrap();
                let c2 = &mut rest[0];
                matmul_at_b_acc_multi(
                    [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                    &at,
                    [&bs[0], &bs[1], &bs[2]],
                    k,
                    m,
                    n,
                );
            }
            assert_eq!(single_acc, multi_acc, "matmul_at_b_acc_multi diverged at {m}x{k}x{n}");

            // matmul_set_packed_multi vs single packed calls.
            let packed: Vec<PackedB> = bs.iter().map(|b| PackedB::pack(b, k, n)).collect();
            let mut multi_packed: Vec<Vec<f32>> =
                (0..MULTI_B).map(|_| vec![f32::NAN; m * n]).collect();
            {
                let (c0, rest) = multi_packed.split_first_mut().unwrap();
                let (c1, rest) = rest.split_first_mut().unwrap();
                let c2 = &mut rest[0];
                matmul_set_packed_multi(
                    [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                    &a,
                    [&packed[0], &packed[1], &packed[2]],
                    m,
                );
            }
            assert_eq!(single, multi_packed, "matmul_set_packed_multi diverged at {m}x{k}x{n}");
        }
    }

    #[test]
    fn layernorm_rows_matches_stats_output() {
        let mut rng = Pcg64::from_seed(4);
        let (rows, d) = (5, 16);
        let x = randv(&mut rng, rows * d);
        let scale = randv(&mut rng, d);
        let bias = randv(&mut rng, d);
        let (y, mean, inv) = layernorm_stats(&x, &scale, &bias, rows, d);
        assert_eq!(y, layernorm_rows(&x, &scale, &bias, rows, d));
        assert_eq!(mean.len(), rows);
        assert!(inv.iter().all(|&v| v > 0.0));
    }

    /// The forward/decode head kernels claim to fully overwrite `probs` and
    /// `ctx` — prove it by running once from zeroed buffers and once from
    /// NaN-poisoned ones (a leftover NaN would fail the bitwise compare).
    #[test]
    fn attention_fully_overwrites_output_buffers() {
        let mut rng = Pcg64::from_seed(21);
        let (b, s, h, hd) = (2, 7, 3, 5);
        let d = h * hd;
        let q = randv(&mut rng, b * s * d);
        let k = randv(&mut rng, b * s * d);
        let v = randv(&mut rng, b * s * d);
        let mut probs = vec![0.0f32; b * h * s * s];
        let mut ctx = vec![0.0f32; b * s * d];
        attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
        let mut probs_g = vec![f32::NAN; b * h * s * s];
        let mut ctx_g = vec![f32::NAN; b * s * d];
        attention_forward(b, s, h, hd, &q, &k, &v, &mut probs_g, &mut ctx_g);
        assert_eq!(probs, probs_g, "probs must be fully overwritten");
        assert_eq!(ctx, ctx_g, "ctx must be fully overwritten");

        let pos = s - 1;
        let mut qlast = vec![0.0f32; b * d];
        for r in 0..b {
            qlast[r * d..(r + 1) * d]
                .copy_from_slice(&q[(r * s + pos) * d..(r * s + pos + 1) * d]);
        }
        let mut step = vec![0.0f32; b * d];
        attention_decode_step(b, s, pos, h, hd, &qlast, &k, &v, &mut step);
        let mut step_g = vec![f32::NAN; b * d];
        attention_decode_step(b, s, pos, h, hd, &qlast, &k, &v, &mut step_g);
        assert_eq!(step, step_g, "decode ctx must be fully overwritten");
    }

    /// Scalar twin vs AVX2 twin, bit-for-bit, on every lane-shaped kernel:
    /// attention forward/backward/decode and LayerNorm, at a ragged shape
    /// whose `hd` and window lengths straddle the 8-lane width.
    #[test]
    fn attention_and_layernorm_scalar_vs_simd_bit_identical() {
        let _g = serial_guard();
        if !simd_available() {
            eprintln!("skipping attention scalar-vs-SIMD bit-equality: no AVX2 on this host");
            return;
        }
        let mut rng = Pcg64::from_seed(22);
        let (b, s, h, hd) = (2, 13, 3, 11);
        let d = h * hd;
        let q = randv(&mut rng, b * s * d);
        let k = randv(&mut rng, b * s * d);
        let v = randv(&mut rng, b * s * d);
        let dctx = randv(&mut rng, b * s * d);
        let lsc = randv(&mut rng, d);
        let lbs = randv(&mut rng, d);
        let mut results: Vec<Vec<Vec<f32>>> = Vec::new();
        for isa in [KernelIsa::Scalar, KernelIsa::Avx2] {
            set_kernel_override(Some(isa));
            let mut probs = vec![0.0f32; b * h * s * s];
            let mut ctx = vec![0.0f32; b * s * d];
            attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
            let mut dq = vec![0.0f32; b * s * d];
            let mut dk = vec![0.0f32; b * s * d];
            let mut dv = vec![0.0f32; b * s * d];
            attention_backward(b, s, h, hd, &probs, &q, &k, &v, &dctx, &mut dq, &mut dk, &mut dv);
            let mut step = vec![0.0f32; b * d];
            attention_decode_step(b, s, s - 1, h, hd, &q[..b * d], &k, &v, &mut step);
            let (ln_y, ln_m, ln_i) = layernorm_stats(&q, &lsc, &lbs, b * s, d);
            results.push(vec![probs, ctx, dq, dk, dv, step, ln_y, ln_m, ln_i]);
        }
        set_kernel_override(None);
        let names = ["probs", "ctx", "dq", "dk", "dv", "decode ctx", "ln y", "ln mean", "ln inv"];
        for (vi, name) in names.iter().enumerate() {
            assert_eq!(results[0][vi], results[1][vi], "{name} diverged between scalar and SIMD");
        }
    }
}
