//! Shared dense-math kernels for the native backend, with a small
//! `std::thread` worker pool that parallelises matmul/attention over rows.
//!
//! Every kernel here is used by *both* halves of the system: the
//! incremental decode sessions (`super::kv`) and the train/prox
//! forward-backward paths (`super::model`). Parallel execution never
//! changes results: work is split by output rows and each output element
//! accumulates in exactly the same scalar order as the serial loop, so
//! threaded and single-threaded runs are bit-identical (the decode-parity
//! tests rely on this).
//!
//! Pool sizing: `A3PO_THREADS` overrides; the default is
//! `available_parallelism` capped at [`MAX_THREADS`]. Kernels fall back to
//! the serial path for small operands (below [`PAR_MIN_WORK`] multiply-adds)
//! where fan-out overhead would dominate, or when
//! [`set_force_serial`]`(true)` is active (benches use this to measure the
//! threading speedup in-process).

#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};

/// Hard cap on pool size (beyond this, the tiny matmuls here stop scaling).
pub const MAX_THREADS: usize = 16;

/// Minimum multiply-add count before a kernel fans out to the pool.
const PAR_MIN_WORK: usize = 1 << 17;

static FORCE_SERIAL: AtomicBool = AtomicBool::new(false);

/// Force every kernel onto the serial path (process-global). Results are
/// identical either way; benches toggle this to isolate the thread-pool
/// contribution to throughput.
pub fn set_force_serial(v: bool) {
    FORCE_SERIAL.store(v, Ordering::SeqCst);
}

pub fn force_serial() -> bool {
    FORCE_SERIAL.load(Ordering::SeqCst)
}

// ---------------------------------------------------------------------------
// Worker pool

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn complete_one(&self) {
        let mut g = self.remaining.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock().unwrap();
        while *g > 0 {
            g = self.cv.wait(g).unwrap();
        }
    }
}

/// Completion is signalled from a `Drop` guard so a panicking job still
/// releases the caller instead of deadlocking `Latch::wait`.
struct DoneGuard {
    latch: Arc<Latch>,
}

impl Drop for DoneGuard {
    fn drop(&mut self) {
        self.latch.complete_one();
    }
}

/// A fixed set of persistent worker threads fed through one shared channel.
pub struct WorkerPool {
    workers: usize,
    tx: Option<Mutex<mpsc::Sender<Job>>>,
}

impl WorkerPool {
    fn new(workers: usize) -> WorkerPool {
        if workers <= 1 {
            return WorkerPool { workers: 1, tx: None };
        }
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..workers {
            let rx = rx.clone();
            std::thread::Builder::new()
                .name(format!("a3po-kernel-{i}"))
                .spawn(move || loop {
                    let msg = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match msg {
                        Ok(job) => job(),
                        Err(_) => return,
                    }
                })
                .expect("spawning kernel worker");
        }
        WorkerPool { workers, tx: Some(Mutex::new(tx)) }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run a batch of jobs, blocking until every one has finished. Jobs may
    /// borrow from the caller's stack: the blocking wait is what makes the
    /// internal lifetime erasure sound. Panics if any job panicked.
    pub fn run<'a>(&self, mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        match jobs.len() {
            0 => return,
            1 => {
                (jobs.pop().unwrap())();
                return;
            }
            _ => {}
        }
        let tx = match &self.tx {
            Some(tx) if !force_serial() => tx,
            _ => {
                for job in jobs {
                    job();
                }
                return;
            }
        };
        let latch = Arc::new(Latch {
            remaining: Mutex::new(jobs.len()),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let tx = tx.lock().unwrap();
            for job in jobs {
                // SAFETY: `run` blocks on the latch until every submitted
                // job has completed (the Drop guard fires even on panic), so
                // all borrows captured in `job` strictly outlive its
                // execution. Only the lifetime is erased; the layout of the
                // boxed trait object is unchanged.
                let job: Job = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'a>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(job)
                };
                let latch = latch.clone();
                tx.send(Box::new(move || {
                    let guard = DoneGuard { latch };
                    if catch_unwind(AssertUnwindSafe(job)).is_err() {
                        guard.latch.panicked.store(true, Ordering::SeqCst);
                    }
                    drop(guard);
                }))
                .expect("kernel pool channel closed");
            }
        }
        latch.wait();
        if latch.panicked.load(Ordering::SeqCst) {
            panic!("a kernel worker job panicked");
        }
    }
}

/// The process-global kernel pool (created on first use).
pub fn pool() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let n = std::env::var("A3PO_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
            .clamp(1, MAX_THREADS);
        WorkerPool::new(n)
    })
}

/// Should an op of `work` multiply-adds with `rows` splittable rows fan out?
fn parallel_ok(rows: usize, work: usize) -> bool {
    rows >= 2 && work >= PAR_MIN_WORK && pool().workers() >= 2 && !force_serial()
}

/// Rows per job when splitting `rows` across the pool.
#[allow(clippy::manual_div_ceil)] // usize::div_ceil needs rustc >= 1.73
fn rows_per_job(rows: usize) -> usize {
    let parts = pool().workers().max(1);
    ((rows + parts - 1) / parts).max(1)
}

// ---------------------------------------------------------------------------
// Matmul family (row-major; identical accumulation order serial/parallel)

/// c[m,n] += a[m,k] · b[k,n]
pub fn matmul_acc<'a>(c: &'a mut [f32], a: &'a [f32], b: &'a [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if !parallel_ok(m, m * k * n) {
        matmul_acc_chunk(c, a, b, k, n);
        return;
    }
    let rows = rows_per_job(m);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>> = Vec::new();
    for (ci, cc) in c.chunks_mut(rows * n).enumerate() {
        let r0 = ci * rows;
        let r1 = r0 + cc.len() / n;
        let ac = &a[r0 * k..r1 * k];
        jobs.push(Box::new(move || matmul_acc_chunk(cc, ac, b, k, n)));
    }
    pool().run(jobs);
}

fn matmul_acc_chunk(c: &mut [f32], a: &[f32], b: &[f32], k: usize, n: usize) {
    let m = c.len() / n;
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// c[m,n] = a[m,k] · b[k,n]
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    matmul_acc(&mut c, a, b, m, k, n);
    c
}

/// c[m,n] += aᵀ · b where a is [k,m] and b is [k,n] (weight gradients).
pub fn matmul_at_b_acc<'a>(
    c: &'a mut [f32],
    a: &'a [f32],
    b: &'a [f32],
    k: usize,
    m: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if !parallel_ok(m, m * k * n) {
        matmul_at_b_chunk(c, a, b, k, m, n, 0);
        return;
    }
    let rows = rows_per_job(m);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>> = Vec::new();
    for (ci, cc) in c.chunks_mut(rows * n).enumerate() {
        let i0 = ci * rows;
        jobs.push(Box::new(move || matmul_at_b_chunk(cc, a, b, k, m, n, i0)));
    }
    pool().run(jobs);
}

/// The `i0`-offset chunk of aᵀ·b: fills `c` rows `i0..i0 + c.len()/n`.
/// Keeps the serial p-outer order so per-element accumulation matches.
fn matmul_at_b_chunk(c: &mut [f32], a: &[f32], b: &[f32], k: usize, m: usize, n: usize, i0: usize) {
    let rows = c.len() / n;
    for p in 0..k {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for i in 0..rows {
            let av = arow[i0 + i];
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// c[m,n] += a · bᵀ where a is [m,k] and b is [n,k] (input gradients).
pub fn matmul_a_bt_acc<'a>(
    c: &'a mut [f32],
    a: &'a [f32],
    b: &'a [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    if !parallel_ok(m, m * k * n) {
        matmul_a_bt_chunk(c, a, b, k, n);
        return;
    }
    let rows = rows_per_job(m);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>> = Vec::new();
    for (ci, cc) in c.chunks_mut(rows * n).enumerate() {
        let r0 = ci * rows;
        let r1 = r0 + cc.len() / n;
        let ac = &a[r0 * k..r1 * k];
        jobs.push(Box::new(move || matmul_a_bt_chunk(cc, ac, b, k, n)));
    }
    pool().run(jobs);
}

fn matmul_a_bt_chunk(c: &mut [f32], a: &[f32], b: &[f32], k: usize, n: usize) {
    let m = c.len() / n;
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for p in 0..k {
                acc += arow[p] * brow[p];
            }
            crow[j] += acc;
        }
    }
}

// ---------------------------------------------------------------------------
// GELU (tanh approximation — jax.nn.gelu's default) and LayerNorm

const GELU_C: f32 = 0.797_884_6; // sqrt(2/pi)
const GELU_K: f32 = 0.044_715;

pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + GELU_K * x * x * x)).tanh())
}

pub fn gelu_grad(x: f32) -> f32 {
    let u = GELU_C * (x + GELU_K * x * x * x);
    let th = u.tanh();
    let sech2 = 1.0 - th * th;
    0.5 * (1.0 + th) + 0.5 * x * sech2 * GELU_C * (1.0 + 3.0 * GELU_K * x * x)
}

pub const LN_EPS: f32 = 1e-5;

/// Re-zero `buf` to exactly `n` elements, keeping its allocation. The
/// workspace idiom: `clear` drops the length without touching capacity, so
/// after warm-up `resize` never reallocates.
pub fn reset(buf: &mut Vec<f32>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

/// LayerNorm over `rows` rows of width `d`; returns `(y, mean, inv_std)`.
/// The training path keeps mean/inv for its backward; decode ignores them.
pub fn layernorm_stats(
    x: &[f32],
    scale: &[f32],
    bias: &[f32],
    rows: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (mut y, mut mean, mut inv) = (Vec::new(), Vec::new(), Vec::new());
    layernorm_stats_into(x, scale, bias, rows, d, &mut y, &mut mean, &mut inv);
    (y, mean, inv)
}

/// [`layernorm_stats`] writing into caller-owned buffers (resized here),
/// so the train workspace reuses its allocations every step.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_stats_into(
    x: &[f32],
    scale: &[f32],
    bias: &[f32],
    rows: usize,
    d: usize,
    y: &mut Vec<f32>,
    mean: &mut Vec<f32>,
    inv: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), rows * d);
    reset(y, rows * d);
    reset(inv, rows);
    reset(mean, rows);
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let iv = 1.0 / (var + LN_EPS).sqrt();
        mean[r] = mu;
        inv[r] = iv;
        let out = &mut y[r * d..(r + 1) * d];
        for j in 0..d {
            out[j] = (row[j] - mu) * iv * scale[j] + bias[j];
        }
    }
}

/// LayerNorm returning only the normalised output (the decode hot path).
pub fn layernorm_rows(x: &[f32], scale: &[f32], bias: &[f32], rows: usize, d: usize) -> Vec<f32> {
    layernorm_stats(x, scale, bias, rows, d).0
}

// ---------------------------------------------------------------------------
// Causal multi-head attention (full window + incremental decode step)

/// Causal attention over a full `[b, s]` window. `q`/`k`/`v` are `[b, s, d]`
/// with per-head column blocks; fills `probs` `[b, h, s, s]` and
/// accumulates into `ctx` `[b, s, d]` (callers pass zeroed buffers).
/// Parallel over batch rows: each row's output block is independent.
pub fn attention_forward<'a>(
    b: usize,
    s: usize,
    h: usize,
    hd: usize,
    q: &'a [f32],
    k: &'a [f32],
    v: &'a [f32],
    probs: &'a mut [f32],
    ctx: &'a mut [f32],
) {
    let d = h * hd;
    debug_assert_eq!(probs.len(), b * h * s * s);
    debug_assert_eq!(ctx.len(), b * s * d);
    if !parallel_ok(b, b * h * s * s * hd) {
        for bi in 0..b {
            attention_forward_row(
                s,
                h,
                hd,
                &q[bi * s * d..(bi + 1) * s * d],
                &k[bi * s * d..(bi + 1) * s * d],
                &v[bi * s * d..(bi + 1) * s * d],
                &mut probs[bi * h * s * s..(bi + 1) * h * s * s],
                &mut ctx[bi * s * d..(bi + 1) * s * d],
            );
        }
        return;
    }
    let mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>> = Vec::with_capacity(b);
    for (bi, (pc, cc)) in probs.chunks_mut(h * s * s).zip(ctx.chunks_mut(s * d)).enumerate() {
        let qc = &q[bi * s * d..(bi + 1) * s * d];
        let kc = &k[bi * s * d..(bi + 1) * s * d];
        let vc = &v[bi * s * d..(bi + 1) * s * d];
        jobs.push(Box::new(move || attention_forward_row(s, h, hd, qc, kc, vc, pc, cc)));
    }
    pool().run(jobs);
}

/// One batch row of causal attention (`q`/`k`/`v` row-local `[s, d]`).
fn attention_forward_row(
    s: usize,
    h: usize,
    hd: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &mut [f32],
    ctx: &mut [f32],
) {
    let d = h * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores: Vec<f32> = Vec::with_capacity(s);
    for hh in 0..h {
        let col = hh * hd;
        for i in 0..s {
            let qrow = &q[i * d + col..i * d + col + hd];
            let prow_base = (hh * s + i) * s;
            let mut mx = f32::NEG_INFINITY;
            scores.clear();
            for j in 0..=i {
                let krow = &k[j * d + col..j * d + col + hd];
                let mut acc = 0.0f32;
                for t in 0..hd {
                    acc += qrow[t] * krow[t];
                }
                let sc = acc * scale;
                mx = mx.max(sc);
                scores.push(sc);
            }
            let mut denom = 0.0f32;
            for sc in scores.iter_mut() {
                *sc = (*sc - mx).exp();
                denom += *sc;
            }
            let crow = &mut ctx[i * d + col..i * d + col + hd];
            for j in 0..=i {
                let pj = scores[j] / denom;
                probs[prow_base + j] = pj;
                let vrow = &v[j * d + col..j * d + col + hd];
                for t in 0..hd {
                    crow[t] += pj * vrow[t];
                }
            }
        }
    }
}

/// Backward of [`attention_forward`]: given `dctx` `[b, s, d]` and the
/// forward's `probs`/`q`/`k`/`v`, accumulates into `dq`/`dk`/`dv`
/// (zeroed by the caller). Parallel over batch rows.
pub fn attention_backward<'a>(
    b: usize,
    s: usize,
    h: usize,
    hd: usize,
    probs: &'a [f32],
    q: &'a [f32],
    k: &'a [f32],
    v: &'a [f32],
    dctx: &'a [f32],
    dq: &'a mut [f32],
    dk: &'a mut [f32],
    dv: &'a mut [f32],
) {
    let d = h * hd;
    if !parallel_ok(b, 2 * b * h * s * s * hd) {
        for bi in 0..b {
            attention_backward_row(
                s,
                h,
                hd,
                &probs[bi * h * s * s..(bi + 1) * h * s * s],
                &q[bi * s * d..(bi + 1) * s * d],
                &k[bi * s * d..(bi + 1) * s * d],
                &v[bi * s * d..(bi + 1) * s * d],
                &dctx[bi * s * d..(bi + 1) * s * d],
                &mut dq[bi * s * d..(bi + 1) * s * d],
                &mut dk[bi * s * d..(bi + 1) * s * d],
                &mut dv[bi * s * d..(bi + 1) * s * d],
            );
        }
        return;
    }
    let mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>> = Vec::with_capacity(b);
    let iter = dq
        .chunks_mut(s * d)
        .zip(dk.chunks_mut(s * d))
        .zip(dv.chunks_mut(s * d))
        .enumerate();
    for (bi, ((dqc, dkc), dvc)) in iter {
        let pc = &probs[bi * h * s * s..(bi + 1) * h * s * s];
        let qc = &q[bi * s * d..(bi + 1) * s * d];
        let kc = &k[bi * s * d..(bi + 1) * s * d];
        let vc = &v[bi * s * d..(bi + 1) * s * d];
        let dc = &dctx[bi * s * d..(bi + 1) * s * d];
        jobs.push(Box::new(move || {
            attention_backward_row(s, h, hd, pc, qc, kc, vc, dc, dqc, dkc, dvc)
        }));
    }
    pool().run(jobs);
}

fn attention_backward_row(
    s: usize,
    h: usize,
    hd: usize,
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dctx: &[f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    let d = h * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dprobs_row = vec![0.0f32; s];
    for hh in 0..h {
        let col = hh * hd;
        for i in 0..s {
            let prow_base = (hh * s + i) * s;
            let dcrow = &dctx[i * d + col..i * d + col + hd];
            // dprobs and dv.
            let mut rowdot = 0.0f32;
            for j in 0..=i {
                let pj = probs[prow_base + j];
                let vrow = &v[j * d + col..j * d + col + hd];
                let mut acc = 0.0f32;
                for t in 0..hd {
                    acc += dcrow[t] * vrow[t];
                }
                dprobs_row[j] = acc;
                rowdot += acc * pj;
                let dvrow = &mut dv[j * d + col..j * d + col + hd];
                for t in 0..hd {
                    dvrow[t] += pj * dcrow[t];
                }
            }
            // dscores -> dq, dk.
            let q_start = i * d + col;
            for j in 0..=i {
                let pj = probs[prow_base + j];
                let dscore = pj * (dprobs_row[j] - rowdot) * scale;
                if dscore == 0.0 {
                    continue;
                }
                let k_start = j * d + col;
                for t in 0..hd {
                    dq[q_start + t] += dscore * k[k_start + t];
                    dk[k_start + t] += dscore * q[q_start + t];
                }
            }
        }
    }
}

/// One incremental decode step of causal attention: each row's single query
/// at position `pos` attends over its `pos + 1` cached keys. `q` is
/// `[rows, d]`; `kcache`/`vcache` are `[rows, cap, d]`; accumulates into
/// `ctx` `[rows, d]` (zeroed by the caller). Parallel over rows.
pub fn attention_decode_step<'a>(
    rows: usize,
    cap: usize,
    pos: usize,
    h: usize,
    hd: usize,
    q: &'a [f32],
    kcache: &'a [f32],
    vcache: &'a [f32],
    ctx: &'a mut [f32],
) {
    let d = h * hd;
    debug_assert!(pos < cap);
    debug_assert_eq!(q.len(), rows * d);
    debug_assert!(kcache.len() >= rows * cap * d);
    debug_assert_eq!(ctx.len(), rows * d);
    if !parallel_ok(rows, rows * (pos + 1) * d) {
        for r in 0..rows {
            attention_decode_row(
                cap,
                pos,
                h,
                hd,
                &q[r * d..(r + 1) * d],
                &kcache[r * cap * d..(r + 1) * cap * d],
                &vcache[r * cap * d..(r + 1) * cap * d],
                &mut ctx[r * d..(r + 1) * d],
            );
        }
        return;
    }
    let per = rows_per_job(rows);
    let mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>> = Vec::new();
    for (ci, cc) in ctx.chunks_mut(per * d).enumerate() {
        let r0 = ci * per;
        let nr = cc.len() / d;
        let qc = &q[r0 * d..(r0 + nr) * d];
        let kc = &kcache[r0 * cap * d..(r0 + nr) * cap * d];
        let vc = &vcache[r0 * cap * d..(r0 + nr) * cap * d];
        jobs.push(Box::new(move || {
            for r in 0..nr {
                attention_decode_row(
                    cap,
                    pos,
                    h,
                    hd,
                    &qc[r * d..(r + 1) * d],
                    &kc[r * cap * d..(r + 1) * cap * d],
                    &vc[r * cap * d..(r + 1) * cap * d],
                    &mut cc[r * d..(r + 1) * d],
                );
            }
        }));
    }
    pool().run(jobs);
}

/// One row of decode attention (`q` `[d]`, caches `[cap, d]`, `ctx` `[d]`).
/// Same online-softmax arithmetic (and scalar order) as the full-window
/// kernel at position `pos`, so session logits match full-forward decode.
fn attention_decode_row(
    cap: usize,
    pos: usize,
    h: usize,
    hd: usize,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    ctx: &mut [f32],
) {
    debug_assert!(pos < cap);
    let d = h * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores: Vec<f32> = Vec::with_capacity(pos + 1);
    for hh in 0..h {
        let col = hh * hd;
        let qrow = &q[col..col + hd];
        let mut mx = f32::NEG_INFINITY;
        scores.clear();
        for j in 0..=pos {
            let krow = &kc[j * d + col..j * d + col + hd];
            let mut acc = 0.0f32;
            for t in 0..hd {
                acc += qrow[t] * krow[t];
            }
            let sc = acc * scale;
            mx = mx.max(sc);
            scores.push(sc);
        }
        let mut denom = 0.0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - mx).exp();
            denom += *sc;
        }
        let crow = &mut ctx[col..col + hd];
        for j in 0..=pos {
            let pj = scores[j] / denom;
            let vrow = &vc[j * d + col..j * d + col + hd];
            for t in 0..hd {
                crow[t] += pj * vrow[t];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randv(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    /// Textbook triple-loop reference.
    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a[i * k + p] as f64 * b[p * n + j] as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn pool_runs_borrowed_jobs_to_completion() {
        let mut out = vec![0u32; 64];
        {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
            for (i, chunk) in out.chunks_mut(8).enumerate() {
                jobs.push(Box::new(move || {
                    for (j, c) in chunk.iter_mut().enumerate() {
                        *c = (i * 8 + j) as u32;
                    }
                }));
            }
            pool().run(jobs);
        }
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i as u32);
        }
    }

    #[test]
    fn pool_propagates_job_panics() {
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("boom")),
                Box::new(|| {}),
            ];
            pool().run(jobs);
        }));
        // Single-worker pools run inline and propagate directly; multi-worker
        // pools re-panic from the latch. Either way the caller sees a panic.
        assert!(res.is_err());
    }

    #[test]
    fn matmul_matches_naive_and_is_thread_invariant() {
        let mut rng = Pcg64::from_seed(1);
        // Large enough to cross the parallel threshold on multicore hosts.
        let (m, k, n) = (96, 64, 48);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let c = matmul(&a, &b, m, k, n);
        let reference = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&reference) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
        set_force_serial(true);
        let c_serial = matmul(&a, &b, m, k, n);
        set_force_serial(false);
        assert_eq!(c, c_serial, "threaded matmul must be bit-identical to serial");
    }

    #[test]
    fn at_b_and_a_bt_match_transposed_naive() {
        let mut rng = Pcg64::from_seed(2);
        let (m, k, n) = (40, 96, 32);
        // c[m,n] += aᵀ·b with a: [k,m].
        let a = randv(&mut rng, k * m);
        let b = randv(&mut rng, k * n);
        let mut c = vec![0.0f32; m * n];
        matmul_at_b_acc(&mut c, &a, &b, k, m, n);
        let mut at = vec![0.0f32; m * k];
        for p in 0..k {
            for i in 0..m {
                at[i * k + p] = a[p * m + i];
            }
        }
        let reference = naive_matmul(&at, &b, m, k, n);
        for (x, y) in c.iter().zip(&reference) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }

        // c[m,n] += a·bᵀ with b: [n,k].
        let a2 = randv(&mut rng, m * k);
        let b2 = randv(&mut rng, n * k);
        let mut c2 = vec![0.0f32; m * n];
        matmul_a_bt_acc(&mut c2, &a2, &b2, m, k, n);
        let mut b2t = vec![0.0f32; k * n];
        for j in 0..n {
            for p in 0..k {
                b2t[p * n + j] = b2[j * k + p];
            }
        }
        let reference2 = naive_matmul(&a2, &b2t, m, k, n);
        for (x, y) in c2.iter().zip(&reference2) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn decode_attention_matches_full_window_last_position() {
        let mut rng = Pcg64::from_seed(3);
        let (b, s, h, hd) = (3, 6, 2, 4);
        let d = h * hd;
        let q = randv(&mut rng, b * s * d);
        let k = randv(&mut rng, b * s * d);
        let v = randv(&mut rng, b * s * d);
        let mut probs = vec![0.0f32; b * h * s * s];
        let mut ctx = vec![0.0f32; b * s * d];
        attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);

        // Same data laid out as decode caches [rows, cap, d]; query = last pos.
        let pos = s - 1;
        let mut qlast = vec![0.0f32; b * d];
        for r in 0..b {
            qlast[r * d..(r + 1) * d].copy_from_slice(&q[(r * s + pos) * d..(r * s + pos + 1) * d]);
        }
        let mut ctx_step = vec![0.0f32; b * d];
        attention_decode_step(b, s, pos, h, hd, &qlast, &k, &v, &mut ctx_step);
        for r in 0..b {
            let full = &ctx[(r * s + pos) * d..(r * s + pos + 1) * d];
            let step = &ctx_step[r * d..(r + 1) * d];
            assert_eq!(full, step, "row {r}: decode-step attention diverged");
        }
    }

    #[test]
    fn layernorm_rows_matches_stats_output() {
        let mut rng = Pcg64::from_seed(4);
        let (rows, d) = (5, 16);
        let x = randv(&mut rng, rows * d);
        let scale = randv(&mut rng, d);
        let bias = randv(&mut rng, d);
        let (y, mean, inv) = layernorm_stats(&x, &scale, &bias, rows, d);
        assert_eq!(y, layernorm_rows(&x, &scale, &bias, rows, d));
        assert_eq!(mean.len(), rows);
        assert!(inv.iter().all(|&v| v > 0.0));
    }
}
