//! Stateful train sessions for the native backend, plus the step math
//! shared with the positional `train_*`/`pretrain` executables.
//!
//! [`train_step_impl`]/[`pretrain_step_impl`] are the single source of truth
//! for the fused A-3PO loss (paper Eq. 2/3), the backward pass, and the Adam
//! update. The positional executables in [`super`] call them with freshly
//! cloned state and a throwaway [`StepWorkspace`] (the historical cost
//! profile); [`NativeTrainSession`] calls them with state and workspace it
//! owns across steps — identical math, no per-step parameter/moment copies
//! and no activation reallocation.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::runtime::backend::{TrainInputs, TrainSession, TrainSessionFactory, TrainStepOutput};
use crate::runtime::params::ParamSnapshot;
use crate::runtime::tensor::HostTensor;
use crate::runtime::train::TrainState;
use crate::trace;

use super::kernels;
use super::model::{self, BackwardWs, Cache, Dims, SeqStats};
use super::{masked_sum, LossMode, NativePreset, N_METRICS};

/// Every activation, gradient, and scratch buffer one train step needs,
/// sized on first use from the preset geometry and reused afterwards.
pub struct StepWorkspace {
    cache: Cache,
    stats: SeqStats,
    /// Parameter gradients in manifest order (re-zeroed each backward).
    grads: Vec<Vec<f32>>,
    bws: BackwardWs,
    dlogits: Vec<f32>,
    dlogp: Vec<f32>,
}

impl StepWorkspace {
    pub fn new(dims: &Dims) -> StepWorkspace {
        StepWorkspace {
            cache: Cache::empty(dims),
            stats: SeqStats::empty(),
            grads: dims.param_specs().iter().map(|sp| vec![0.0f32; sp.elements()]).collect(),
            bws: BackwardWs::new(),
            dlogits: Vec::new(),
            dlogp: Vec::new(),
        }
    }
}

/// Dense-GEMM FLOPs of one full train step (all `n_minibatch` passes over
/// the `[train_batch, seq_len]` window): the forward's GEMMs plus the
/// backward's two gradient GEMMs per forward GEMM — the 3x rule of thumb.
/// Benches divide this by measured step time for GFLOP/s.
pub fn train_step_gemm_flops(preset: &NativePreset) -> u64 {
    let rows = preset.train_batch * preset.seq_len();
    3 * preset.dims.forward_gemm_flops(rows)
}

/// One RL step over the full train batch: `n_minibatch` sequential
/// forward/backward/Adam passes mutating `params`/`adam_m`/`adam_v`/`step`
/// in place. `theta_out` receives the θ log-probs `[tb, t]`. The caller
/// validates input lengths (and that `Frozen` mode has `prox_logp`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn train_step_impl(
    preset: &NativePreset,
    mode: LossMode,
    params: &mut [Vec<f32>],
    adam_m: &mut [Vec<f32>],
    adam_v: &mut [Vec<f32>],
    step: &mut i32,
    inputs: &TrainInputs<'_>,
    ws: &mut StepWorkspace,
    theta_out: &mut Vec<f32>,
) -> [f32; N_METRICS] {
    let dims = &preset.dims;
    let (tb, s) = (preset.train_batch, preset.seq_len());
    let t = s - 1;
    let n_mb = preset.n_minibatch;
    let mb = tb / n_mb;
    let clip_eps = preset.clip_eps;

    kernels::reset(theta_out, tb * t);
    let mut losses = 0.0f64;
    let mut ents = 0.0f64;
    let mut ratios = 0.0f64;
    let mut kls = 0.0f64;
    let mut gnorms = 0.0f64;
    let mut max_iw = f32::NEG_INFINITY;
    let mut min_iw = f32::INFINITY;
    let mut clip_total = 0.0f32;

    for i in 0..n_mb {
        let (r0, r1) = (i * mb, (i + 1) * mb);
        let tok_mb = &inputs.tokens[r0 * s..r1 * s];
        let mask_mb = &inputs.mask[r0 * t..r1 * t];
        let behav_mb = &inputs.behav_logp[r0 * t..r1 * t];
        let adv_mb = &inputs.adv[r0 * t..r1 * t];
        let alpha_mb = &inputs.alpha[r0..r1];
        let prox_mb = inputs.prox_logp.map(|p| &p[r0 * t..r1 * t]);

        let p: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
        {
            let _sp = trace::span_arg("forward", "train", "minibatch", i as f64);
            model::forward_into(dims, &p, tok_mb, mb, s, &mut ws.cache);
            model::sequence_logp_into(dims, &ws.cache, tok_mb, &mut ws.stats);
        }
        theta_out[r0 * t..r1 * t].copy_from_slice(&ws.stats.logp);

        let denom = mask_mb.iter().sum::<f32>().max(1.0);
        let mut obj_sum = 0.0f32;
        let mut ent_sum = 0.0f32;
        let mut ratio_sum = 0.0f32;
        let mut kl_sum = 0.0f32;
        let mut clip_sum = 0.0f32;
        let mut mb_max_iw = f32::NEG_INFINITY;
        let mut mb_min_iw = f32::INFINITY;
        kernels::reset(&mut ws.dlogp, mb * t);
        for row in 0..mb {
            let a = alpha_mb[row];
            for ti in 0..t {
                let idx = row * t + ti;
                let mk = mask_mb[idx];
                let theta = ws.stats.logp[idx];
                let bh = behav_mb[idx];
                // The anchor is detached in every mode (paper Eq. 3):
                // the objective's only gradient path is θ in the ratio.
                let prox = match mode {
                    LossMode::Coupled => bh,
                    LossMode::Frozen => prox_mb.expect("frozen mode needs prox_logp")[idx],
                    LossMode::Interp => a * bh + (1.0 - a) * theta,
                };
                let iw = (prox - bh).exp();
                let ratio = (theta - prox).exp();
                let av = adv_mb[idx];
                let unclipped = ratio * av;
                let clipped_term = ratio.clamp(1.0 - clip_eps, 1.0 + clip_eps) * av;
                let is_clipped = if unclipped > clipped_term { 1.0f32 } else { 0.0 };
                let obj = iw * unclipped.min(clipped_term);
                if mk > 0.0 {
                    obj_sum += obj * mk;
                    ent_sum += ws.stats.entropy[idx] * mk;
                    ratio_sum += ratio * mk;
                    kl_sum += (bh - theta) * mk;
                    clip_sum += is_clipped * mk;
                    mb_max_iw = mb_max_iw.max(iw);
                    mb_min_iw = mb_min_iw.min(iw);
                    // loss = -sum(obj*mask)/denom; unclipped branch only.
                    ws.dlogp[idx] = -mk * iw * av * ratio * (1.0 - is_clipped) / denom;
                }
            }
        }

        {
            let _sp = trace::span_arg("backward", "train", "minibatch", i as f64);
            model::dlogits_from_dlogp_into(
                dims,
                &ws.cache,
                &ws.stats,
                tok_mb,
                &ws.dlogp,
                &mut ws.dlogits,
            );
            model::backward_into(
                dims,
                &p,
                &ws.cache,
                tok_mb,
                &ws.dlogits,
                &mut ws.grads,
                &mut ws.bws,
            );
        }
        drop(p);
        let adam_span = trace::span_arg("adam", "train", "minibatch", i as f64);
        let gnorm = model::adam_update(
            &preset.adam,
            preset.rl_lr,
            params,
            adam_m,
            adam_v,
            &ws.grads,
            *step,
        );
        drop(adam_span);
        *step += 1;

        losses += (-obj_sum / denom) as f64;
        ents += (ent_sum / denom) as f64;
        ratios += (ratio_sum / denom) as f64;
        kls += (kl_sum / denom) as f64;
        gnorms += gnorm as f64;
        max_iw = max_iw.max(mb_max_iw);
        min_iw = min_iw.min(mb_min_iw);
        clip_total += clip_sum;
    }

    let inv = 1.0 / n_mb as f64;
    [
        (losses * inv) as f32,
        (ents * inv) as f32,
        max_iw,
        min_iw,
        clip_total,
        (ratios * inv) as f32,
        (gnorms * inv) as f32,
        (kls * inv) as f32,
    ]
}

/// One supervised warm-up step over the full train batch (single pass, no
/// minibatching — matches the positional `pretrain` executable).
#[allow(clippy::too_many_arguments)]
pub(crate) fn pretrain_step_impl(
    preset: &NativePreset,
    params: &mut [Vec<f32>],
    adam_m: &mut [Vec<f32>],
    adam_v: &mut [Vec<f32>],
    step: &mut i32,
    tokens: &[i32],
    mask: &[f32],
    ws: &mut StepWorkspace,
) -> [f32; N_METRICS] {
    let dims = &preset.dims;
    let (b, s) = (preset.train_batch, preset.seq_len());

    let p: Vec<&[f32]> = params.iter().map(|v| v.as_slice()).collect();
    model::forward_into(dims, &p, tokens, b, s, &mut ws.cache);
    model::sequence_logp_into(dims, &ws.cache, tokens, &mut ws.stats);
    let denom = mask.iter().sum::<f32>().max(1.0);
    let loss = -masked_sum(&ws.stats.logp, mask) / denom;
    let entropy = masked_sum(&ws.stats.entropy, mask) / denom;

    // d(-masked_mean(logp))/dlogp = -mask/denom.
    ws.dlogp.clear();
    ws.dlogp.extend(mask.iter().map(|&mk| -mk / denom));
    model::dlogits_from_dlogp_into(dims, &ws.cache, &ws.stats, tokens, &ws.dlogp, &mut ws.dlogits);
    model::backward_into(dims, &p, &ws.cache, tokens, &ws.dlogits, &mut ws.grads, &mut ws.bws);
    drop(p);
    let gnorm =
        model::adam_update(&preset.adam, preset.lr, params, adam_m, adam_v, &ws.grads, *step);
    *step += 1;
    [loss, entropy, 0.0, 0.0, 0.0, 0.0, gnorm, 0.0]
}

/// The native backend's [`TrainSession`]: owns parameters, Adam moments,
/// the step counter, and a [`StepWorkspace`], all mutated in place.
pub struct NativeTrainSession {
    preset: NativePreset,
    mode: LossMode,
    params: Vec<Vec<f32>>,
    adam_m: Vec<Vec<f32>>,
    adam_v: Vec<Vec<f32>>,
    opt_step: i32,
    ws: StepWorkspace,
    theta_buf: Vec<f32>,
}

impl NativeTrainSession {
    fn pack(&self, group: &[Vec<f32>]) -> Vec<HostTensor> {
        self.preset
            .dims
            .param_specs()
            .iter()
            .zip(group)
            .map(|(spec, data)| HostTensor::f32(spec.shape.clone(), data.clone()))
            .collect()
    }
}

impl TrainSession for NativeTrainSession {
    fn opt_step(&self) -> i32 {
        self.opt_step
    }

    fn train_step(&mut self, inputs: &TrainInputs<'_>) -> Result<TrainStepOutput> {
        let (tb, s) = (self.preset.train_batch, self.preset.seq_len());
        let t = s - 1;
        if inputs.tokens.len() != tb * s {
            bail!("tokens: {} elements, expected [{tb}, {s}]", inputs.tokens.len());
        }
        for (name, buf) in [
            ("mask", inputs.mask),
            ("behav_logp", inputs.behav_logp),
            ("adv", inputs.adv),
        ] {
            if buf.len() != tb * t {
                bail!("{name}: {} elements, expected [{tb}, {t}]", buf.len());
            }
        }
        if inputs.alpha.len() != tb {
            bail!("alpha: {} elements, expected [{tb}]", inputs.alpha.len());
        }
        match inputs.prox_logp {
            Some(p) if p.len() != tb * t => {
                bail!("prox_logp: {} elements, expected [{tb}, {t}]", p.len())
            }
            None if self.mode == LossMode::Frozen => {
                bail!("frozen-anchor mode requires prox_logp")
            }
            _ => {}
        }
        let metrics = train_step_impl(
            &self.preset,
            self.mode,
            &mut self.params,
            &mut self.adam_m,
            &mut self.adam_v,
            &mut self.opt_step,
            inputs,
            &mut self.ws,
            &mut self.theta_buf,
        );
        Ok(TrainStepOutput {
            metrics: metrics.to_vec(),
            theta_logp: Some(self.theta_buf.clone()),
        })
    }

    fn pretrain_step(&mut self, tokens: &[i32], mask: &[f32]) -> Result<TrainStepOutput> {
        let (tb, s) = (self.preset.train_batch, self.preset.seq_len());
        let t = s - 1;
        if tokens.len() != tb * s {
            bail!("tokens: {} elements, expected [{tb}, {s}]", tokens.len());
        }
        if mask.len() != tb * t {
            bail!("mask: {} elements, expected [{tb}, {t}]", mask.len());
        }
        let metrics = pretrain_step_impl(
            &self.preset,
            &mut self.params,
            &mut self.adam_m,
            &mut self.adam_v,
            &mut self.opt_step,
            tokens,
            mask,
            &mut self.ws,
        );
        Ok(TrainStepOutput { metrics: metrics.to_vec(), theta_logp: None })
    }

    fn snapshot_params(&self) -> Result<Vec<HostTensor>> {
        Ok(self.pack(&self.params))
    }

    fn export_state(&self) -> Result<TrainState> {
        Ok(TrainState {
            opt_step: self.opt_step,
            params: self.pack(&self.params),
            adam_m: self.pack(&self.adam_m),
            adam_v: self.pack(&self.adam_v),
        })
    }
}

/// Creates [`NativeTrainSession`]s, keyed by train-executable name so the
/// runtime stays decoupled from `crate::config::Method`.
pub struct NativeTrainFactory {
    preset: NativePreset,
}

impl NativeTrainFactory {
    pub fn new(preset: NativePreset) -> NativeTrainFactory {
        NativeTrainFactory { preset }
    }
}

impl TrainSessionFactory for NativeTrainFactory {
    fn start(
        &self,
        train_exec: &str,
        initial: &Arc<ParamSnapshot>,
    ) -> Result<Box<dyn TrainSession>> {
        let mode = match train_exec {
            "train_sync" => LossMode::Coupled,
            "train_recompute" => LossMode::Frozen,
            "train_loglinear" => LossMode::Interp,
            other => bail!(
                "native train sessions exist for train_sync|train_recompute|train_loglinear, \
                 not {other:?}"
            ),
        };
        let specs = self.preset.dims.param_specs();
        if initial.params.len() != specs.len() {
            bail!(
                "initial snapshot has {} tensors, preset {} expects {}",
                initial.params.len(),
                self.preset.name,
                specs.len()
            );
        }
        let mut params = Vec::with_capacity(specs.len());
        for (tensor, spec) in initial.params.iter().zip(&specs) {
            tensor.check(spec)?;
            params.push(tensor.as_f32()?.to_vec());
        }
        let zeros: Vec<Vec<f32>> = specs.iter().map(|sp| vec![0.0f32; sp.elements()]).collect();
        Ok(Box::new(NativeTrainSession {
            mode,
            params,
            adam_m: zeros.clone(),
            adam_v: zeros,
            opt_step: 0,
            ws: StepWorkspace::new(&self.preset.dims),
            theta_buf: Vec::new(),
            preset: self.preset.clone(),
        }))
    }
}
