//! Per-layer KV-cache decode sessions for the native backend.
//!
//! A session prefills the prompt window with one batched full forward pass
//! (reusing [`model::forward`]'s per-layer key/value activations), then
//! advances one token per active row per step: each step recomputes only
//! the new position — O(S·L) per token instead of the full-forward decode
//! executable's O(S²·L) — with attention reading the cached keys/values.
//!
//! Every arithmetic step reproduces the full-window forward exactly (same
//! kernels, same scalar accumulation order), so session logits are
//! bit-identical to the `decode` executable's at the same position; the
//! `decode_parity` integration tests pin this within 1e-4.
//!
//! Because a session's weights are frozen to one snapshot, every layer's
//! projection matrices (and the unembed) are packed into the blocked GEMM
//! panel layout **once at session start** and reused every token — the
//! per-step matmuls skip the pack pass entirely. Step scratch lives in a
//! [`StepBuffers`] workspace sized on first use and recycled per token, so
//! the steady-state decode loop performs no heap allocation.

#![allow(clippy::needless_range_loop)]

use std::sync::Arc;

use anyhow::{bail, Result};

use super::kernels;
use super::model::{
    self, Dims, L_B1, L_B2, L_LN1B, L_LN1S, L_LN2B, L_LN2S, L_W1, L_W2, L_WK, L_WO, L_WQ, L_WV,
};
use crate::runtime::backend::{DecodeSession, DecodeSessionFactory};
use crate::runtime::params::ParamSnapshot;

/// Creates KV-cache sessions for one native preset.
pub struct NativeDecodeFactory {
    dims: Dims,
    /// Token-window capacity per session (the preset's `seq_len`).
    window: usize,
}

impl NativeDecodeFactory {
    pub fn new(dims: Dims, window: usize) -> NativeDecodeFactory {
        NativeDecodeFactory { dims, window }
    }
}

impl DecodeSessionFactory for NativeDecodeFactory {
    fn start(
        &self,
        snapshot: &Arc<ParamSnapshot>,
        prompts: &[i32],
        rows: usize,
        prompt_len: usize,
    ) -> Result<Box<dyn DecodeSession>> {
        Ok(Box::new(NativeDecodeSession::start(
            self.dims.clone(),
            self.window,
            snapshot.clone(),
            prompts,
            rows,
            prompt_len,
        )?))
    }
}

/// One layer's projection weights, pre-packed into the blocked GEMM panel
/// layout (the session's snapshot is immutable, so packing happens once).
struct LayerWeights {
    wq: kernels::PackedB,
    wk: kernels::PackedB,
    wv: kernels::PackedB,
    wo: kernels::PackedB,
    w1: kernels::PackedB,
    w2: kernels::PackedB,
}

/// Per-step scratch, sized on first use and reused every token.
#[derive(Default)]
struct StepBuffers {
    /// Residual stream for the new position `[rows, d]`.
    x: Vec<f32>,
    /// LayerNorm output (reused sequentially for ln1 / ln2 / lnf).
    ln_y: Vec<f32>,
    ln_mean: Vec<f32>,
    ln_inv: Vec<f32>,
    q: Vec<f32>,
    knew: Vec<f32>,
    vnew: Vec<f32>,
    ctx: Vec<f32>,
    /// Output projection scratch (reused for the wo and w2 projections).
    proj: Vec<f32>,
    mlp_pre: Vec<f32>,
    mlp_act: Vec<f32>,
}

/// One live KV-cache decode session (weights pinned to one snapshot).
pub struct NativeDecodeSession {
    dims: Dims,
    snapshot: Arc<ParamSnapshot>,
    /// Active (still-generating) rows; caches are compacted on retain.
    rows: usize,
    /// Tokens appended so far per row (all rows advance in lockstep).
    len: usize,
    /// Cache capacity in positions (the session's token window).
    cap: usize,
    /// Per-layer keys `[rows, cap, d]`.
    kcache: Vec<Vec<f32>>,
    /// Per-layer values `[rows, cap, d]`.
    vcache: Vec<Vec<f32>>,
    /// Next-token logits `[rows, vocab]` for position `len`.
    logits: Vec<f32>,
    /// Per-layer weights packed once for the blocked GEMM fast path.
    packed: Vec<LayerWeights>,
    /// The `[d, vocab]` unembedding, packed once.
    unembed: kernels::PackedB,
    /// Reused per-step scratch.
    bufs: StepBuffers,
}

impl NativeDecodeSession {
    pub fn start(
        dims: Dims,
        window: usize,
        snapshot: Arc<ParamSnapshot>,
        prompts: &[i32],
        rows: usize,
        prompt_len: usize,
    ) -> Result<NativeDecodeSession> {
        if rows == 0 {
            bail!("decode session needs at least one row");
        }
        if prompt_len == 0 {
            bail!("decode session needs a non-empty prompt");
        }
        if window > dims.max_seq {
            bail!("decode window {} exceeds max_seq {}", window, dims.max_seq);
        }
        if prompt_len >= window {
            bail!("prompt_len {prompt_len} leaves no room to generate in a {window}-token window");
        }
        if prompts.len() != rows * prompt_len {
            bail!(
                "prompt buffer has {} tokens, expected rows {} x prompt_len {}",
                prompts.len(),
                rows,
                prompt_len
            );
        }
        for &t in prompts {
            if t < 0 || t as usize >= dims.vocab {
                bail!("prompt token {} out of vocab {}", t, dims.vocab);
            }
        }
        if snapshot.params.len() != dims.n_params() {
            bail!(
                "snapshot has {} tensors, model needs {}",
                snapshot.params.len(),
                dims.n_params()
            );
        }

        let (d, v, f) = (dims.d_model, dims.vocab, dims.d_ff);
        let cap = window;
        let (kcache, vcache, logits, packed, unembed) = {
            let p: Vec<&[f32]> =
                snapshot.params.iter().map(|t| t.as_f32()).collect::<Result<Vec<_>>>()?;
            // Batched prefill: one full forward over the prompt window seeds
            // every layer's KV cache and the first next-token logits.
            // Deliberately reuses the training-path forward even though it
            // also materialises probs/LN caches and unembeds every prompt
            // position (a few percent of prefill at these geometries): one
            // shared code path is what guarantees bit-level parity with the
            // full-forward decode executable.
            let cache = model::forward(&dims, &p, prompts, rows, prompt_len);
            let mut kcache = Vec::with_capacity(dims.n_layers);
            let mut vcache = Vec::with_capacity(dims.n_layers);
            for lc in &cache.layers {
                let mut kbuf = vec![0.0f32; rows * cap * d];
                let mut vbuf = vec![0.0f32; rows * cap * d];
                for r in 0..rows {
                    kbuf[r * cap * d..r * cap * d + prompt_len * d]
                        .copy_from_slice(&lc.k[r * prompt_len * d..(r + 1) * prompt_len * d]);
                    vbuf[r * cap * d..r * cap * d + prompt_len * d]
                        .copy_from_slice(&lc.v[r * prompt_len * d..(r + 1) * prompt_len * d]);
                }
                kcache.push(kbuf);
                vcache.push(vbuf);
            }
            let mut logits = vec![0.0f32; rows * v];
            for r in 0..rows {
                let src = (r * prompt_len + prompt_len - 1) * v;
                logits[r * v..(r + 1) * v].copy_from_slice(&cache.logits[src..src + v]);
            }
            // Pack every per-step weight operand once; steps reuse the
            // panels for the whole session (results stay bit-identical to
            // the unpacked kernels — same blocked accumulation order).
            let mut packed = Vec::with_capacity(dims.n_layers);
            for layer in 0..dims.n_layers {
                let base = dims.layer_base(layer);
                packed.push(LayerWeights {
                    wq: kernels::PackedB::pack(p[base + L_WQ], d, d),
                    wk: kernels::PackedB::pack(p[base + L_WK], d, d),
                    wv: kernels::PackedB::pack(p[base + L_WV], d, d),
                    wo: kernels::PackedB::pack(p[base + L_WO], d, d),
                    w1: kernels::PackedB::pack(p[base + L_W1], d, f),
                    w2: kernels::PackedB::pack(p[base + L_W2], f, d),
                });
            }
            let unembed = kernels::PackedB::pack(p[dims.unembed_idx()], d, v);
            (kcache, vcache, logits, packed, unembed)
        };
        Ok(NativeDecodeSession {
            dims,
            snapshot,
            rows,
            len: prompt_len,
            cap,
            kcache,
            vcache,
            logits,
            packed,
            unembed,
            bufs: StepBuffers::default(),
        })
    }

    /// Incremental forward over the single new position `self.len`.
    fn step_impl(&mut self, new_tokens: &[i32]) -> Result<()> {
        let rows = self.rows;
        if rows == 0 {
            bail!("decode session has no active rows");
        }
        if new_tokens.len() != rows {
            bail!("step got {} tokens for {} active rows", new_tokens.len(), rows);
        }
        // Same boundary as the full-forward fallback: the appended token must
        // land in-window AND the resulting logits must predict an in-window
        // position (len + 1 < cap), so both DecodeSession implementations
        // exhaust at the same step count.
        if self.len + 1 >= self.cap {
            bail!("decode window exhausted at {} of {} tokens", self.len, self.cap);
        }
        // Borrow-split: caches, scratch, and packed weights are disjoint
        // fields, so the per-layer loop can hold &mut to several at once.
        let NativeDecodeSession {
            dims,
            snapshot,
            len,
            cap,
            kcache,
            vcache,
            logits,
            packed,
            unembed,
            bufs,
            ..
        } = self;
        let (d, v, f, h, hd) =
            (dims.d_model, dims.vocab, dims.d_ff, dims.n_heads, dims.head_dim());
        let pos = *len;
        let cap = *cap;
        let p: Vec<&[f32]> =
            snapshot.params.iter().map(|t| t.as_f32()).collect::<Result<Vec<_>>>()?;
        let StepBuffers { x, ln_y, ln_mean, ln_inv, q, knew, vnew, ctx, proj, mlp_pre, mlp_act } =
            bufs;

        // Embedding + positional for the one new token per row.
        let embed = p[0];
        let pos_embed = p[1];
        x.resize(rows * d, 0.0);
        for r in 0..rows {
            let tok = new_tokens[r];
            if tok < 0 || tok as usize >= v {
                bail!("token {} out of vocab {}", tok, v);
            }
            let e = &embed[tok as usize * d..(tok as usize + 1) * d];
            let pe = &pos_embed[pos * d..(pos + 1) * d];
            let out = &mut x[r * d..(r + 1) * d];
            for j in 0..d {
                out[j] = e[j] + pe[j];
            }
        }

        for (layer, lw) in packed.iter().enumerate() {
            let base = dims.layer_base(layer);
            kernels::layernorm_stats_into(
                x,
                p[base + L_LN1S],
                p[base + L_LN1B],
                rows,
                d,
                ln_y,
                ln_mean,
                ln_inv,
            );
            q.resize(rows * d, 0.0);
            knew.resize(rows * d, 0.0);
            vnew.resize(rows * d, 0.0);
            // Fused q/k/v projection against the session's pre-packed weight
            // panels — bit-identical to three matmul_set_packed calls.
            kernels::matmul_set_packed_multi(
                [q.as_mut_slice(), knew.as_mut_slice(), vnew.as_mut_slice()],
                ln_y,
                [&lw.wq, &lw.wk, &lw.wv],
                rows,
            );
            {
                let kc = &mut kcache[layer];
                let vc = &mut vcache[layer];
                for r in 0..rows {
                    let at = (r * cap + pos) * d;
                    kc[at..at + d].copy_from_slice(&knew[r * d..(r + 1) * d]);
                    vc[at..at + d].copy_from_slice(&vnew[r * d..(r + 1) * d]);
                }
            }
            // The decode kernel fully overwrites ctx; no zero sweep needed.
            ctx.resize(rows * d, 0.0);
            kernels::attention_decode_step(
                rows,
                cap,
                pos,
                h,
                hd,
                q,
                &kcache[layer],
                &vcache[layer],
                ctx,
            );
            proj.resize(rows * d, 0.0);
            kernels::matmul_set_packed(proj, ctx, &lw.wo, rows);
            for j in 0..rows * d {
                x[j] += proj[j];
            }

            kernels::layernorm_stats_into(
                x,
                p[base + L_LN2S],
                p[base + L_LN2B],
                rows,
                d,
                ln_y,
                ln_mean,
                ln_inv,
            );
            mlp_pre.resize(rows * f, 0.0);
            mlp_act.resize(rows * f, 0.0);
            kernels::matmul_set_bias_gelu_packed(
                mlp_pre,
                mlp_act,
                ln_y,
                &lw.w1,
                p[base + L_B1],
                rows,
            );
            proj.resize(rows * d, 0.0);
            kernels::matmul_set_packed(proj, mlp_act, &lw.w2, rows);
            let b2 = p[base + L_B2];
            for r in 0..rows {
                let xr = &mut x[r * d..(r + 1) * d];
                let mr = &proj[r * d..(r + 1) * d];
                for j in 0..d {
                    xr[j] += mr[j] + b2[j];
                }
            }
        }

        kernels::layernorm_stats_into(
            x,
            p[dims.lnf_scale_idx()],
            p[dims.lnf_scale_idx() + 1],
            rows,
            d,
            ln_y,
            ln_mean,
            ln_inv,
        );
        logits.resize(rows * v, 0.0);
        kernels::matmul_set_packed(logits, ln_y, unembed, rows);
        *len += 1;
        Ok(())
    }
}

impl DecodeSession for NativeDecodeSession {
    fn active_rows(&self) -> usize {
        self.rows
    }

    fn logits(&self) -> &[f32] {
        &self.logits
    }

    fn step(&mut self, new_tokens: &[i32]) -> Result<()> {
        self.step_impl(new_tokens)
    }

    fn retain_rows(&mut self, keep: &[bool]) -> Result<()> {
        if keep.len() != self.rows {
            bail!("retain mask has {} entries for {} active rows", keep.len(), self.rows);
        }
        let survivors: Vec<usize> = (0..self.rows).filter(|&r| keep[r]).collect();
        if survivors.len() == self.rows {
            return Ok(());
        }
        let d = self.dims.d_model;
        let v = self.dims.vocab;
        let row_elems = self.cap * d;
        for layer in 0..self.dims.n_layers {
            for (dst, &src) in survivors.iter().enumerate() {
                if dst != src {
                    self.kcache[layer]
                        .copy_within(src * row_elems..(src + 1) * row_elems, dst * row_elems);
                    self.vcache[layer]
                        .copy_within(src * row_elems..(src + 1) * row_elems, dst * row_elems);
                }
            }
        }
        for (dst, &src) in survivors.iter().enumerate() {
            if dst != src {
                self.logits.copy_within(src * v..(src + 1) * v, dst * v);
            }
        }
        self.rows = survivors.len();
        self.logits.truncate(self.rows * v);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    fn session_fixture() -> (Dims, usize, Arc<ParamSnapshot>) {
        let rt = Runtime::native("tiny", Some(&["init"])).unwrap();
        let snapshot = rt.init_params(9).unwrap();
        let preset = super::super::preset("tiny").unwrap();
        (preset.dims, preset.seq_len(), snapshot)
    }

    fn prompt_tokens(rows: usize, pl: usize, vocab: usize) -> Vec<i32> {
        (0..rows * pl).map(|i| (3 + i * 5 % (vocab - 3)) as i32).collect()
    }

    #[test]
    fn start_validates_geometry_and_tokens() {
        let (dims, window, snapshot) = session_fixture();
        let pl = 4;
        let ok = prompt_tokens(2, pl, dims.vocab);
        assert!(NativeDecodeSession::start(dims.clone(), window, snapshot.clone(), &ok, 2, pl)
            .is_ok());
        // Empty batch / empty prompt / overflowing prompt / bad token.
        assert!(NativeDecodeSession::start(dims.clone(), window, snapshot.clone(), &[], 0, pl)
            .is_err());
        assert!(NativeDecodeSession::start(dims.clone(), window, snapshot.clone(), &[], 2, 0)
            .is_err());
        let long = prompt_tokens(2, window, dims.vocab);
        assert!(NativeDecodeSession::start(
            dims.clone(),
            window,
            snapshot.clone(),
            &long,
            2,
            window
        )
        .is_err());
        let mut bad = ok.clone();
        bad[0] = dims.vocab as i32;
        assert!(NativeDecodeSession::start(dims, window, snapshot, &bad, 2, pl).is_err());
    }

    #[test]
    fn step_rejects_wrong_arity_and_window_overflow() {
        let (dims, window, snapshot) = session_fixture();
        let pl = window - 2;
        let prompts = prompt_tokens(2, pl, dims.vocab);
        let mut s = NativeDecodeSession::start(dims, window, snapshot, &prompts, 2, pl).unwrap();
        assert!(s.step(&[3]).is_err(), "one token for two rows");
        // One step allowed: token lands at window-2, logits predict the
        // final in-window position — the same exhaustion point as the
        // full-forward fallback session.
        s.step(&[3, 4]).unwrap();
        assert!(s.step(&[5, 6]).is_err(), "window boundary must match the fallback session");
    }

    #[test]
    fn retained_session_matches_fresh_subset_session() {
        // Dropping rows mid-generation must leave the survivors' caches
        // exactly as if the dropped rows never existed.
        let (dims, window, snapshot) = session_fixture();
        let (rows, pl) = (4, 6);
        let prompts = prompt_tokens(rows, pl, dims.vocab);
        let mut full =
            NativeDecodeSession::start(dims.clone(), window, snapshot.clone(), &prompts, rows, pl)
                .unwrap();
        full.step(&[3, 4, 5, 6]).unwrap();
        full.retain_rows(&[true, false, true, false]).unwrap();
        assert_eq!(full.active_rows(), 2);
        full.step(&[7, 8]).unwrap();

        // Fresh session over only rows 0 and 2, replaying the same tokens.
        let mut subset_prompts = Vec::new();
        for &r in &[0usize, 2] {
            subset_prompts.extend_from_slice(&prompts[r * pl..(r + 1) * pl]);
        }
        let mut fresh =
            NativeDecodeSession::start(dims, window, snapshot, &subset_prompts, 2, pl).unwrap();
        fresh.step(&[3, 5]).unwrap();
        fresh.step(&[7, 8]).unwrap();

        assert_eq!(full.logits().len(), fresh.logits().len());
        for (a, b) in full.logits().iter().zip(fresh.logits()) {
            assert!((a - b).abs() <= 1e-5, "retained {a} vs fresh {b}");
        }
    }
}
