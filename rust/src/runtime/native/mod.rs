//! Native CPU backend: every executable the coordinator needs, as plain
//! Rust math — no XLA, no artifacts, no Python.
//!
//! The model, losses, and optimiser mirror `python/compile/` exactly
//! (same parameter order, same Eq. 2/3 objective and detached-anchor
//! gradient, same Adam with bias correction and global-norm clipping, same
//! metric vector layout), so a preset trained natively is indistinguishable
//! in structure from a PJRT run — just smaller and hermetic. Presets
//! `tiny`, `setup1`, `setup2`, and `big` are built in and mirror
//! `python/compile/config.py`.

pub mod kernels;
pub mod kv;
pub mod model;
pub mod train;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use super::backend::{
    Backend, DecodeSessionFactory, ExecutableImpl, TrainInputs, TrainSessionFactory,
};
use super::manifest::{Dtype, ExecSpec, Manifest, PresetConfig, TensorSpec};
use super::tensor::HostTensor;

use model::{AdamHp, Dims};

/// Number of entries in the train-metric vector (layout in
/// `crate::metrics::TRAIN_METRIC_NAMES`).
pub const N_METRICS: usize = 8;

/// One built-in experimental setup (mirrors python `RunConfig`).
#[derive(Debug, Clone)]
pub struct NativePreset {
    pub name: &'static str,
    pub dims: Dims,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub group_size: usize,
    pub rollout_batch: usize,
    pub train_batch: usize,
    pub n_minibatch: usize,
    /// Supervised warm-start learning rate.
    pub lr: f32,
    /// RL learning rate (much lower, post-training regime).
    pub rl_lr: f32,
    pub adam: AdamHp,
    pub clip_eps: f32,
    pub temperature: f64,
}

impl NativePreset {
    pub fn seq_len(&self) -> usize {
        self.prompt_len + self.gen_len
    }
}

const ADAM: AdamHp = AdamHp { b1: 0.9, b2: 0.95, eps: 1e-8, grad_clip: 1.0 };

/// Look up a built-in preset (same table as `python/compile/config.py`).
pub fn preset(name: &str) -> Option<NativePreset> {
    let p = match name {
        "tiny" => NativePreset {
            name: "tiny",
            dims: Dims { vocab: 64, d_model: 64, n_layers: 2, n_heads: 4, d_ff: 128, max_seq: 32 },
            prompt_len: 12,
            gen_len: 8,
            group_size: 4,
            rollout_batch: 16,
            train_batch: 16,
            n_minibatch: 4,
            lr: 1e-3,
            rl_lr: 2e-4,
            adam: ADAM,
            clip_eps: 0.2,
            temperature: 1.0,
        },
        "setup1" => NativePreset {
            name: "setup1",
            dims: Dims { vocab: 64, d_model: 192, n_layers: 4, n_heads: 6, d_ff: 768, max_seq: 48 },
            prompt_len: 16,
            gen_len: 10,
            group_size: 4,
            rollout_batch: 32,
            train_batch: 64,
            n_minibatch: 4,
            lr: 4e-4,
            rl_lr: 5e-5,
            adam: ADAM,
            clip_eps: 0.2,
            temperature: 1.0,
        },
        "setup2" => NativePreset {
            name: "setup2",
            dims: Dims {
                vocab: 64,
                d_model: 256,
                n_layers: 6,
                n_heads: 8,
                d_ff: 1024,
                max_seq: 64,
            },
            prompt_len: 36,
            gen_len: 12,
            group_size: 4,
            rollout_batch: 32,
            train_batch: 64,
            n_minibatch: 4,
            lr: 3e-4,
            rl_lr: 5e-5,
            adam: ADAM,
            clip_eps: 0.2,
            temperature: 1.0,
        },
        "big" => NativePreset {
            name: "big",
            dims: Dims {
                vocab: 64,
                d_model: 768,
                n_layers: 12,
                n_heads: 12,
                d_ff: 3072,
                max_seq: 64,
            },
            prompt_len: 36,
            gen_len: 12,
            group_size: 4,
            rollout_batch: 16,
            train_batch: 32,
            n_minibatch: 4,
            lr: 2e-4,
            rl_lr: 5e-5,
            adam: ADAM,
            clip_eps: 0.2,
            temperature: 1.0,
        },
        _ => return None,
    };
    Some(p)
}

pub fn preset_names() -> &'static [&'static str] {
    &["tiny", "setup1", "setup2", "big"]
}

// ---------------------------------------------------------------------------
// Manifest synthesis

fn tensor(name: &str, shape: &[usize], dtype: Dtype) -> TensorSpec {
    TensorSpec { name: name.into(), shape: shape.to_vec(), dtype }
}

/// Build the same manifest `python/compile/aot.py` would emit for this
/// preset — entirely in memory, no files.
pub fn builtin_manifest(p: &NativePreset) -> Result<Manifest> {
    let s = p.seq_len();
    let t = s - 1;
    let (rb, tb) = (p.rollout_batch, p.train_batch);
    let params = p.dims.param_specs();

    let opt_state = |inputs: &mut Vec<TensorSpec>| {
        for prefix in ["m", "v"] {
            for spec in &params {
                inputs.push(tensor(&format!("{prefix}.{}", spec.name), &spec.shape, Dtype::F32));
            }
        }
        inputs.push(tensor("step", &[], Dtype::I32));
    };
    let opt_outputs = |outputs: &mut Vec<TensorSpec>| {
        for spec in &params {
            outputs.push(spec.clone());
        }
        for prefix in ["m", "v"] {
            for spec in &params {
                outputs.push(tensor(&format!("{prefix}.{}", spec.name), &spec.shape, Dtype::F32));
            }
        }
        outputs.push(tensor("step", &[], Dtype::I32));
        outputs.push(tensor("metrics", &[N_METRICS], Dtype::F32));
    };

    let mut executables = BTreeMap::new();
    let mut add = |name: &str, inputs: Vec<TensorSpec>, outputs: Vec<TensorSpec>| {
        executables.insert(
            name.to_string(),
            ExecSpec {
                name: name.to_string(),
                file: Default::default(),
                inputs,
                outputs,
                hlo_bytes: 0,
            },
        );
    };

    add(
        "init",
        vec![tensor("seed", &[], Dtype::I32)],
        params.clone(),
    );
    {
        let mut inputs = params.clone();
        inputs.push(tensor("tokens", &[rb, s], Dtype::I32));
        inputs.push(tensor("pos", &[], Dtype::I32));
        add("decode", inputs, vec![tensor("logits", &[rb, p.dims.vocab], Dtype::F32)]);
    }
    {
        let mut inputs = params.clone();
        inputs.push(tensor("tokens", &[tb, s], Dtype::I32));
        add("prox_forward", inputs, vec![tensor("prox_logp", &[tb, t], Dtype::F32)]);
    }
    {
        let mut inputs = params.clone();
        opt_state(&mut inputs);
        inputs.push(tensor("tokens", &[tb, s], Dtype::I32));
        inputs.push(tensor("mask", &[tb, t], Dtype::F32));
        let mut outputs = Vec::new();
        opt_outputs(&mut outputs);
        add("pretrain", inputs, outputs);
    }
    for name in ["train_sync", "train_recompute", "train_loglinear"] {
        let mut inputs = params.clone();
        opt_state(&mut inputs);
        inputs.push(tensor("tokens", &[tb, s], Dtype::I32));
        inputs.push(tensor("mask", &[tb, t], Dtype::F32));
        inputs.push(tensor("behav_logp", &[tb, t], Dtype::F32));
        inputs.push(tensor("adv", &[tb, t], Dtype::F32));
        inputs.push(tensor("alpha", &[tb], Dtype::F32));
        inputs.push(tensor("prox_logp", &[tb, t], Dtype::F32));
        let mut outputs = Vec::new();
        opt_outputs(&mut outputs);
        // Native extra: the θ log-probs of the last minibatch pass, so the
        // trainer can seed the next step's standalone Eq. 3 measurement.
        outputs.push(tensor("theta_logp", &[tb, t], Dtype::F32));
        add(name, inputs, outputs);
    }

    let preset_cfg = PresetConfig {
        name: p.name.to_string(),
        vocab: p.dims.vocab,
        seq_len: s,
        prompt_len: p.prompt_len,
        gen_len: p.gen_len,
        group_size: p.group_size,
        rollout_batch: rb,
        train_batch: tb,
        n_minibatch: p.n_minibatch,
        param_count: p.dims.param_count(),
        lr: p.lr as f64,
        temperature: p.temperature,
    };
    let metric_names = crate::metrics::TRAIN_METRIC_NAMES
        .iter()
        .map(|s| s.to_string())
        .collect();
    let m = Manifest {
        dir: Default::default(),
        preset: preset_cfg,
        params,
        metric_names,
        executables,
    };
    m.validate()?;
    Ok(m)
}

// ---------------------------------------------------------------------------
// Backend

pub struct NativeBackend {
    preset: NativePreset,
}

impl NativeBackend {
    pub fn new(name: &str) -> Result<NativeBackend> {
        match preset(name) {
            Some(p) => {
                // One line per process saying which register tile / thread
                // count every subsequent train/decode number came from.
                kernels::log_kernel_path_once();
                Ok(NativeBackend { preset: p })
            }
            None => bail!(
                "unknown native preset {name:?} (built-in: {})",
                preset_names().join("|")
            ),
        }
    }

    pub fn preset(&self) -> &NativePreset {
        &self.preset
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> Result<Manifest> {
        builtin_manifest(&self.preset)
    }

    fn load_executable(&self, spec: &ExecSpec) -> Result<Box<dyn ExecutableImpl>> {
        let kind = match spec.name.as_str() {
            "init" => ExecKind::Init,
            "decode" => ExecKind::Decode,
            "prox_forward" => ExecKind::ProxForward,
            "pretrain" => ExecKind::Pretrain,
            "train_sync" => ExecKind::Train(LossMode::Coupled),
            "train_recompute" => ExecKind::Train(LossMode::Frozen),
            "train_loglinear" => ExecKind::Train(LossMode::Interp),
            other => bail!("native backend has no executable {other:?}"),
        };
        Ok(Box::new(NativeExec { preset: self.preset.clone(), kind }))
    }

    fn decode_session_factory(&self) -> Option<Arc<dyn DecodeSessionFactory>> {
        Some(Arc::new(kv::NativeDecodeFactory::new(
            self.preset.dims.clone(),
            self.preset.seq_len(),
        )))
    }

    fn train_session_factory(&self) -> Option<Arc<dyn TrainSessionFactory>> {
        Some(Arc::new(train::NativeTrainFactory::new(self.preset.clone())))
    }
}

/// The proximal-anchor modes of the fused loss (paper Eq. 2/3; mirrors
/// `python/compile/kernels/a3po_loss.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossMode {
    /// sync GRPO — anchor = behaviour policy (coupled loss).
    Coupled,
    /// decoupled recompute — anchor = explicit `prox_logp` input, frozen.
    Frozen,
    /// A-3PO — anchor = α·behav + (1-α)·θ, detached (Eq. 3).
    Interp,
}

#[derive(Debug, Clone, Copy)]
enum ExecKind {
    Init,
    Decode,
    ProxForward,
    Pretrain,
    Train(LossMode),
}

struct NativeExec {
    preset: NativePreset,
    kind: ExecKind,
}

impl ExecutableImpl for NativeExec {
    fn execute(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        match self.kind {
            ExecKind::Init => self.run_init(inputs),
            ExecKind::Decode => self.run_decode(inputs),
            ExecKind::ProxForward => self.run_prox_forward(inputs),
            ExecKind::Pretrain => self.run_pretrain(inputs),
            ExecKind::Train(mode) => self.run_train(inputs, mode),
        }
    }
}

/// Collect the leading `np` inputs as f32 parameter views.
fn param_views<'a>(inputs: &[&'a HostTensor], np: usize) -> Result<Vec<&'a [f32]>> {
    inputs[..np].iter().map(|t| t.as_f32()).collect()
}

/// Clone a range of inputs into owned mutable buffers.
fn owned_f32(inputs: &[&HostTensor], from: usize, n: usize) -> Result<Vec<Vec<f32>>> {
    inputs[from..from + n]
        .iter()
        .map(|t| Ok(t.as_f32()?.to_vec()))
        .collect()
}

fn masked_sum(values: &[f32], mask: &[f32]) -> f32 {
    values.iter().zip(mask).map(|(v, m)| v * m).sum()
}

impl NativeExec {
    fn np(&self) -> usize {
        self.preset.dims.n_params()
    }

    fn pack_state(
        &self,
        params: Vec<Vec<f32>>,
        m: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
        step: i32,
        metrics: [f32; N_METRICS],
    ) -> Vec<HostTensor> {
        let specs = self.preset.dims.param_specs();
        let mut out = Vec::with_capacity(3 * specs.len() + 2);
        for group in [params, m, v] {
            for (data, spec) in group.into_iter().zip(&specs) {
                out.push(HostTensor::f32(spec.shape.clone(), data));
            }
        }
        out.push(HostTensor::scalar_i32(step));
        out.push(HostTensor::f32(vec![N_METRICS], metrics.to_vec()));
        out
    }

    fn run_init(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let seed = inputs[0].scalar_i32_value()?;
        Ok(model::init_params(&self.preset.dims, seed))
    }

    fn run_decode(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let np = self.np();
        let p = param_views(inputs, np)?;
        let tokens = inputs[np].as_i32()?;
        let pos = inputs[np + 1].scalar_i32_value()?;
        let (b, s, v) = (self.preset.rollout_batch, self.preset.seq_len(), self.preset.dims.vocab);
        // The hidden state at pos-1 predicts the token at pos. A pos outside
        // [1, s) has no in-window predictor; silently clamping (the old
        // behaviour) computed logits for the wrong position.
        if pos < 1 || pos as usize >= s {
            bail!(
                "decode pos {pos} out of range: need 1 <= pos < seq_len {s} \
                 (logits at pos-1 predict pos)"
            );
        }
        let cache = model::forward(&self.preset.dims, &p, tokens, b, s);
        let idx = pos as usize - 1;
        let mut logits = vec![0.0f32; b * v];
        for bi in 0..b {
            logits[bi * v..(bi + 1) * v]
                .copy_from_slice(&cache.logits[(bi * s + idx) * v..(bi * s + idx + 1) * v]);
        }
        Ok(vec![HostTensor::f32(vec![b, v], logits)])
    }

    fn run_prox_forward(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let np = self.np();
        let p = param_views(inputs, np)?;
        let tokens = inputs[np].as_i32()?;
        let (b, s) = (self.preset.train_batch, self.preset.seq_len());
        let cache = model::forward(&self.preset.dims, &p, tokens, b, s);
        let stats = model::sequence_logp(&self.preset.dims, &cache, tokens);
        Ok(vec![HostTensor::f32(vec![b, s - 1], stats.logp)])
    }

    /// Positional pretrain: clones params + both moment sets in, runs the
    /// shared step math with a throwaway workspace, packs everything back
    /// out. The session path ([`train::NativeTrainSession`]) runs the same
    /// math without any of the copies.
    fn run_pretrain(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let np = self.np();
        let mut params = owned_f32(inputs, 0, np)?;
        let mut adam_m = owned_f32(inputs, np, np)?;
        let mut adam_v = owned_f32(inputs, 2 * np, np)?;
        let mut step = inputs[3 * np].scalar_i32_value()?;
        let tokens = inputs[3 * np + 1].as_i32()?;
        let mask = inputs[3 * np + 2].as_f32()?;

        let mut ws = train::StepWorkspace::new(&self.preset.dims);
        let metrics = train::pretrain_step_impl(
            &self.preset,
            &mut params,
            &mut adam_m,
            &mut adam_v,
            &mut step,
            tokens,
            mask,
            &mut ws,
        );
        Ok(self.pack_state(params, adam_m, adam_v, step, metrics))
    }

    /// Positional train step: same copy-in/copy-out framing as
    /// [`Self::run_pretrain`], delegating the loss/backward/Adam loop to
    /// [`train::train_step_impl`].
    fn run_train(&self, inputs: &[&HostTensor], mode: LossMode) -> Result<Vec<HostTensor>> {
        let np = self.np();
        let mut params = owned_f32(inputs, 0, np)?;
        let mut adam_m = owned_f32(inputs, np, np)?;
        let mut adam_v = owned_f32(inputs, 2 * np, np)?;
        let mut step = inputs[3 * np].scalar_i32_value()?;
        let batch = TrainInputs {
            tokens: inputs[3 * np + 1].as_i32()?,
            mask: inputs[3 * np + 2].as_f32()?,
            behav_logp: inputs[3 * np + 3].as_f32()?,
            adv: inputs[3 * np + 4].as_f32()?,
            alpha: inputs[3 * np + 5].as_f32()?,
            prox_logp: Some(inputs[3 * np + 6].as_f32()?),
        };

        let (tb, t) = (self.preset.train_batch, self.preset.seq_len() - 1);
        let mut ws = train::StepWorkspace::new(&self.preset.dims);
        let mut theta_out = Vec::new();
        let metrics = train::train_step_impl(
            &self.preset,
            mode,
            &mut params,
            &mut adam_m,
            &mut adam_v,
            &mut step,
            &batch,
            &mut ws,
            &mut theta_out,
        );
        let mut out = self.pack_state(params, adam_m, adam_v, step, metrics);
        out.push(HostTensor::f32(vec![tb, t], theta_out));
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;

    #[test]
    fn builtin_manifests_validate() {
        for name in preset_names() {
            let p = preset(name).unwrap();
            let m = builtin_manifest(&p).expect(name);
            assert_eq!(m.preset.name, *name);
            assert_eq!(m.preset.seq_len, m.preset.prompt_len + m.preset.gen_len);
            assert_eq!(m.metric_names.len(), N_METRICS);
            assert_eq!(m.n_params(), p.dims.n_params());
        }
        assert!(NativeBackend::new("nope").is_err());
    }

    #[test]
    fn tiny_geometry_matches_python_config() {
        let p = preset("tiny").unwrap();
        assert_eq!(p.seq_len(), 20);
        assert_eq!(p.dims.d_model, 64);
        assert_eq!(p.dims.n_layers, 2);
        assert_eq!(p.train_batch % p.n_minibatch, 0);
    }

    #[test]
    fn decode_rejects_out_of_range_pos() {
        // Regression: pos used to be silently clamped into [0, s), returning
        // logits for the wrong position instead of an error.
        let rt = Runtime::native("tiny", Some(&["init", "decode"])).unwrap();
        let geo = rt.manifest.preset.clone();
        let snapshot = rt.init_params(1).unwrap();
        let decode = rt.exec("decode").unwrap();
        let tokens = HostTensor::i32(
            vec![geo.rollout_batch, geo.seq_len],
            vec![1; geo.rollout_batch * geo.seq_len],
        );
        let run_at = |pos: i32| {
            let pos_t = HostTensor::scalar_i32(pos);
            let mut refs = snapshot.tensor_refs();
            refs.push(&tokens);
            refs.push(&pos_t);
            decode.run_refs(&refs)
        };
        for bad in [0, -3, geo.seq_len as i32, geo.seq_len as i32 + 7] {
            assert!(run_at(bad).is_err(), "pos {bad} must be rejected");
        }
        // Boundaries: 1 (first prediction) and s-1 (last) are valid.
        assert!(run_at(1).is_ok());
        assert!(run_at(geo.seq_len as i32 - 1).is_ok());
    }

    #[test]
    fn sync_step_has_unit_importance_weights() {
        // On-policy coupled loss: behav == anchor, so iw == 1 everywhere and
        // the loss reduces to clipped PPO around the behaviour policy.
        let rt = Runtime::native("tiny", Some(&["init", "train_sync"])).unwrap();
        let geo = rt.manifest.preset.clone();
        let snapshot = rt.init_params(5).unwrap();
        let (b, s) = (geo.train_batch, geo.seq_len);
        let t = s - 1;
        let np = rt.manifest.n_params();

        let zeros_f = |n: usize| vec![0.0f32; n];
        let tokens = HostTensor::i32(vec![b, s], (0..b * s).map(|i| (i % 13) as i32).collect());
        let mask = HostTensor::f32(vec![b, t], vec![1.0; b * t]);
        let behav = HostTensor::f32(vec![b, t], vec![-2.0; b * t]);
        let adv = HostTensor::f32(vec![b, t], (0..b * t).map(|i| ((i % 3) as f32) - 1.0).collect());
        let alpha = HostTensor::f32(vec![b], zeros_f(b));
        let prox = HostTensor::f32(vec![b, t], zeros_f(b * t));
        let step = HostTensor::scalar_i32(0);

        let adam = rt.zero_adam_state();
        let mut refs: Vec<&HostTensor> = snapshot.tensor_refs();
        refs.extend(adam.iter());
        refs.extend(adam.iter());
        refs.push(&step);
        refs.push(&tokens);
        refs.push(&mask);
        refs.push(&behav);
        refs.push(&adv);
        refs.push(&alpha);
        refs.push(&prox);
        let outs = rt.exec("train_sync").unwrap().run_refs(&refs).unwrap();
        assert_eq!(outs.len(), 3 * np + 3);
        let metrics = outs[3 * np + 1].as_f32().unwrap();
        // max_is_weight == min_is_weight == 1 in coupled mode.
        assert!((metrics[2] - 1.0).abs() < 1e-6, "max_iw {}", metrics[2]);
        assert!((metrics[3] - 1.0).abs() < 1e-6, "min_iw {}", metrics[3]);
        // step advanced by n_minibatch.
        assert_eq!(outs[3 * np].as_i32().unwrap()[0], geo.n_minibatch as i32);
        // params actually moved.
        let moved = outs[0].as_f32().unwrap() != snapshot.params[0].as_f32().unwrap();
        assert!(moved, "train step must update parameters");
    }

    #[test]
    fn interp_anchor_contracts_ratio_toward_one() {
        // With alpha = 1 the anchor sits at the behaviour policy (iw = 1,
        // ratio = theta/behav); with alpha = 0 the anchor is theta itself
        // (ratio = 1 exactly, iw = theta/behav). Check the alpha = 0 case:
        // loglinear on-policy anchoring makes every ratio exactly 1, so no
        // tokens clip regardless of advantage.
        let rt = Runtime::native("tiny", Some(&["init", "train_loglinear"])).unwrap();
        let geo = rt.manifest.preset.clone();
        let snapshot = rt.init_params(5).unwrap();
        let (b, s) = (geo.train_batch, geo.seq_len);
        let t = s - 1;
        let np = rt.manifest.n_params();

        let tokens = HostTensor::i32(vec![b, s], (0..b * s).map(|i| (i % 11) as i32).collect());
        let mask = HostTensor::f32(vec![b, t], vec![1.0; b * t]);
        let behav = HostTensor::f32(vec![b, t], vec![-1.5; b * t]);
        let adv = HostTensor::f32(vec![b, t], vec![1.0; b * t]);
        let alpha = HostTensor::f32(vec![b], vec![0.0; b]);
        let prox = HostTensor::f32(vec![b, t], vec![0.0; b * t]);
        let step = HostTensor::scalar_i32(0);

        let adam = rt.zero_adam_state();
        let mut refs: Vec<&HostTensor> = snapshot.tensor_refs();
        refs.extend(adam.iter());
        refs.extend(adam.iter());
        refs.push(&step);
        refs.push(&tokens);
        refs.push(&mask);
        refs.push(&behav);
        refs.push(&adv);
        refs.push(&alpha);
        refs.push(&prox);
        let outs = rt.exec("train_loglinear").unwrap().run_refs(&refs).unwrap();
        let metrics = outs[3 * np + 1].as_f32().unwrap();
        assert_eq!(metrics[4], 0.0, "alpha=0 anchor-at-theta must never clip");
        assert!((metrics[5] - 1.0).abs() < 1e-6, "mean ratio {}", metrics[5]);
        // theta_logp output is a valid log-prob field.
        let theta = outs[3 * np + 2].as_f32().unwrap();
        assert_eq!(theta.len(), b * t);
        assert!(theta.iter().all(|&x| x <= 1e-5 && x.is_finite()));
    }
}
