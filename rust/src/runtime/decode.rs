//! The rollout-facing decode front end.
//!
//! [`Decoder`] hides the gap between backends with incremental decode
//! support (the native backend's KV-cache sessions, `native::kv`) and
//! backends that only expose the full-forward `decode` executable (PJRT):
//! both paths present the same [`DecodeSession`] interface, so the rollout
//! engine is written once against sessions and stays backend-agnostic.
//!
//! The fallback [`FullForwardSession`] reproduces the seed behaviour
//! exactly: it keeps the full `[rollout_batch, seq_len]` token window and
//! re-runs the `decode` executable once per generated position. It is also
//! the reference implementation the decode-parity tests and the
//! `decode_throughput` bench compare the KV path against.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::backend::{DecodeSession, DecodeSessionFactory};
use super::executable::Executable;
use super::manifest::PresetConfig;
use super::params::ParamSnapshot;
use super::tensor::HostTensor;

/// Session front end for one preset's decode path. Cheap to clone (shared
/// executable + factory); every rollout worker carries its own copy.
#[derive(Clone)]
pub struct Decoder {
    exec: Arc<Executable>,
    factory: Option<Arc<dyn DecodeSessionFactory>>,
    geo: PresetConfig,
}

impl Decoder {
    pub fn new(
        exec: Arc<Executable>,
        factory: Option<Arc<dyn DecodeSessionFactory>>,
        geo: PresetConfig,
    ) -> Decoder {
        Decoder { exec, factory, geo }
    }

    /// Does this decoder run incremental KV-cache sessions (vs full-forward
    /// fallback)?
    pub fn incremental(&self) -> bool {
        self.factory.is_some()
    }

    /// The underlying full-forward `decode` executable.
    pub fn exec(&self) -> &Arc<Executable> {
        &self.exec
    }

    /// A copy of this decoder with incremental sessions disabled — every
    /// `start` takes the full-forward path (parity tests, benches).
    pub fn without_sessions(&self) -> Decoder {
        Decoder { exec: self.exec.clone(), factory: None, geo: self.geo.clone() }
    }

    /// Start a decode session: incremental when the backend supports it,
    /// full-forward fallback otherwise.
    pub fn start(
        &self,
        snapshot: &Arc<ParamSnapshot>,
        prompts: &[i32],
        rows: usize,
        prompt_len: usize,
    ) -> Result<Box<dyn DecodeSession>> {
        match &self.factory {
            Some(f) => f.start(snapshot, prompts, rows, prompt_len),
            None => self.start_full_forward(snapshot, prompts, rows, prompt_len),
        }
    }

    /// Start a full-forward fallback session regardless of backend support
    /// (the parity/bench reference path).
    pub fn start_full_forward(
        &self,
        snapshot: &Arc<ParamSnapshot>,
        prompts: &[i32],
        rows: usize,
        prompt_len: usize,
    ) -> Result<Box<dyn DecodeSession>> {
        Ok(Box::new(FullForwardSession::start(
            self.exec.clone(),
            &self.geo,
            snapshot.clone(),
            prompts,
            rows,
            prompt_len,
        )?))
    }
}

impl std::fmt::Debug for Decoder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Decoder({}, {})",
            self.geo.name,
            if self.incremental() { "kv-sessions" } else { "full-forward" }
        )
    }
}

/// Fallback session over the full-forward `decode` executable (the seed
/// path): fixed `[rollout_batch, seq_len]` window, one full forward per
/// generated position, inactive rows padded and ignored.
struct FullForwardSession {
    exec: Arc<Executable>,
    snapshot: Arc<ParamSnapshot>,
    rollout_batch: usize,
    seq_len: usize,
    vocab: usize,
    /// Token window `[rollout_batch, seq_len]` (0-padded; padding never
    /// influences other rows under causal attention).
    window: Vec<i32>,
    /// Original window row index of each active row, in order.
    active: Vec<usize>,
    /// Next position to be predicted/filled.
    pos: usize,
    /// Gathered next-token logits `[active, vocab]`.
    logits: Vec<f32>,
}

impl FullForwardSession {
    fn start(
        exec: Arc<Executable>,
        geo: &PresetConfig,
        snapshot: Arc<ParamSnapshot>,
        prompts: &[i32],
        rows: usize,
        prompt_len: usize,
    ) -> Result<FullForwardSession> {
        if rows != geo.rollout_batch {
            bail!(
                "full-forward decode is fixed to rollout_batch = {} rows, got {}",
                geo.rollout_batch,
                rows
            );
        }
        if prompt_len == 0 || prompt_len >= geo.seq_len {
            bail!("prompt_len {} must be in 1..seq_len {}", prompt_len, geo.seq_len);
        }
        if prompts.len() != rows * prompt_len {
            bail!(
                "prompt buffer has {} tokens, expected rows {} x prompt_len {}",
                prompts.len(),
                rows,
                prompt_len
            );
        }
        let s = geo.seq_len;
        let mut window = vec![0i32; rows * s];
        for r in 0..rows {
            window[r * s..r * s + prompt_len]
                .copy_from_slice(&prompts[r * prompt_len..(r + 1) * prompt_len]);
        }
        let mut session = FullForwardSession {
            exec,
            snapshot,
            rollout_batch: geo.rollout_batch,
            seq_len: s,
            vocab: geo.vocab,
            window,
            active: (0..rows).collect(),
            pos: prompt_len,
            logits: Vec::new(),
        };
        session.forward()?;
        Ok(session)
    }

    /// Run the decode executable at `self.pos` and gather active-row logits.
    fn forward(&mut self) -> Result<()> {
        let tokens_t =
            HostTensor::i32(vec![self.rollout_batch, self.seq_len], self.window.clone());
        let pos_t = HostTensor::scalar_i32(self.pos as i32);
        let mut refs = self.snapshot.tensor_refs();
        refs.push(&tokens_t);
        refs.push(&pos_t);
        let outs = self.exec.run_refs(&refs)?;
        let all = outs[0].as_f32()?;
        let v = self.vocab;
        self.logits.clear();
        for &row in &self.active {
            self.logits.extend_from_slice(&all[row * v..(row + 1) * v]);
        }
        Ok(())
    }
}

impl DecodeSession for FullForwardSession {
    fn active_rows(&self) -> usize {
        self.active.len()
    }

    fn logits(&self) -> &[f32] {
        &self.logits
    }

    fn step(&mut self, new_tokens: &[i32]) -> Result<()> {
        if new_tokens.len() != self.active.len() {
            bail!(
                "step got {} tokens for {} active rows",
                new_tokens.len(),
                self.active.len()
            );
        }
        if self.active.is_empty() {
            bail!("decode session has no active rows");
        }
        if self.pos + 1 >= self.seq_len {
            bail!("decode window exhausted at position {}", self.pos);
        }
        for (i, &row) in self.active.iter().enumerate() {
            self.window[row * self.seq_len + self.pos] = new_tokens[i];
        }
        self.pos += 1;
        self.forward()
    }

    fn retain_rows(&mut self, keep: &[bool]) -> Result<()> {
        if keep.len() != self.active.len() {
            bail!("retain mask has {} entries for {} active rows", keep.len(), self.active.len());
        }
        let v = self.vocab;
        let mut new_active = Vec::with_capacity(self.active.len());
        let mut dst = 0usize;
        for (i, &row) in self.active.iter().enumerate() {
            if keep[i] {
                if dst != i {
                    self.logits.copy_within(i * v..(i + 1) * v, dst * v);
                }
                new_active.push(row);
                dst += 1;
            }
        }
        self.active = new_active;
        self.logits.truncate(self.active.len() * v);
        Ok(())
    }
}
