//! PJRT client wrapper: one CPU client shared by every thread in the
//! process, plus executable loading from HLO text.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO *text* is the interchange
//! format (jax >= 0.5 protos are rejected by xla_extension 0.5.1; the text
//! parser reassigns instruction ids).

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};
use xla::{HloModuleProto, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Process-wide PJRT client.
///
/// SAFETY of `Send + Sync`: the underlying `TfrtCpuClient` (and PJRT client
/// API generally) is thread-safe — compilation and execution may be invoked
/// concurrently from multiple threads. The Rust wrapper types only lack the
/// auto-traits because they hold raw pointers.
pub struct Client {
    inner: PjRtClient,
}

unsafe impl Send for Client {}
unsafe impl Sync for Client {}

impl Client {
    pub fn cpu() -> Result<Arc<Client>> {
        let inner = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Client { inner }))
    }

    pub fn platform(&self) -> String {
        self.inner.platform_name()
    }

    pub fn raw(&self) -> &PjRtClient {
        &self.inner
    }

    /// Load + compile an HLO-text file into a PJRT executable.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        self.inner
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }
}

impl std::fmt::Debug for Client {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Client({})", self.platform())
    }
}
