//! An executable bound to its manifest signature.
//!
//! `Executable` wraps a backend's [`ExecutableImpl`] with input/output
//! arity validation, optional shape checking, and cumulative timing stats
//! (the §Perf reports). The trainer's hot path uses [`Executable::run_refs`]
//! to avoid cloning the parameter tensors every step.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::ExecutableImpl;
use super::manifest::ExecSpec;
use super::tensor::HostTensor;

pub struct Executable {
    imp: Box<dyn ExecutableImpl>,
    pub spec: ExecSpec,
    /// Cumulative execute statistics (used by §Perf reporting).
    stats: std::sync::Mutex<ExecStats>,
}

#[derive(Debug, Default, Clone, Copy)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
}

impl Executable {
    pub fn new(spec: ExecSpec, imp: Box<dyn ExecutableImpl>) -> Arc<Executable> {
        Arc::new(Executable { imp, spec, stats: std::sync::Mutex::new(ExecStats::default()) })
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    /// Execute from borrowed tensors: callers that keep large resident
    /// state (e.g. the trainer's parameters) avoid re-cloning it into each
    /// call. Validates input and output arity against the manifest, but not
    /// shapes. Note a backend may still pack inputs internally (PJRT does).
    pub fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest says {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let t0 = Instant::now();
        let outs = self
            .imp
            .execute(inputs)
            .with_context(|| format!("executing {}", self.spec.name))?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        let mut s = self.stats.lock().unwrap();
        s.calls += 1;
        s.total_secs += t0.elapsed().as_secs_f64();
        Ok(outs)
    }

    /// Execute from owned host tensors, validating shapes against the
    /// manifest signature first.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest says {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            t.check(spec).with_context(|| format!("in {}", self.spec.name))?;
        }
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Executable({})", self.spec.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Dtype, TensorSpec};

    /// Doubles every f32 input — enough to exercise the wrapper contract.
    struct Doubler;

    impl ExecutableImpl for Doubler {
        fn execute(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
            inputs
                .iter()
                .map(|t| {
                    let d = t.as_f32()?;
                    Ok(HostTensor::f32(t.shape().to_vec(), d.iter().map(|x| x * 2.0).collect()))
                })
                .collect()
        }
    }

    fn spec() -> ExecSpec {
        let ts = TensorSpec { name: "x".into(), shape: vec![2], dtype: Dtype::F32 };
        ExecSpec {
            name: "double".into(),
            file: Default::default(),
            inputs: vec![ts.clone()],
            outputs: vec![ts],
            hlo_bytes: 0,
        }
    }

    #[test]
    fn runs_and_counts_stats() {
        let e = Executable::new(spec(), Box::new(Doubler));
        let out = e.run(&[HostTensor::f32(vec![2], vec![1.0, 2.0])]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[2.0, 4.0]);
        assert_eq!(e.stats().calls, 1);
    }

    #[test]
    fn rejects_wrong_arity_and_shape() {
        let e = Executable::new(spec(), Box::new(Doubler));
        assert!(e.run_refs(&[]).is_err());
        assert!(e.run(&[HostTensor::f32(vec![3], vec![0.0; 3])]).is_err());
        assert!(e.run(&[HostTensor::i32(vec![2], vec![0, 1])]).is_err());
    }
}
