//! A loaded executable bound to its manifest signature.
//!
//! `Executable::run` validates input count (and optionally shapes), invokes
//! PJRT, fetches the result tuple to the host, and splits it into literals
//! following the manifest's output signature.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtLoadedExecutable};

use super::client::Client;
use super::manifest::ExecSpec;
use super::tensor::HostTensor;

/// SAFETY: PJRT loaded executables are thread-safe for concurrent Execute
/// calls (the PJRT contract); the wrapper only lacks auto-traits because of
/// raw pointers. Rollout workers share one decode executable.
struct SendExec(PjRtLoadedExecutable);
unsafe impl Send for SendExec {}
unsafe impl Sync for SendExec {}

pub struct Executable {
    exe: SendExec,
    pub spec: ExecSpec,
    /// Cumulative execute statistics (used by §Perf reporting).
    stats: std::sync::Mutex<ExecStats>,
}

#[derive(Debug, Default, Clone, Copy)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
}

impl Executable {
    pub fn load(client: &Arc<Client>, spec: &ExecSpec) -> Result<Arc<Executable>> {
        let t0 = Instant::now();
        let exe = client
            .compile_hlo_file(&spec.file)
            .with_context(|| format!("loading executable {:?}", spec.name))?;
        let dt = t0.elapsed().as_secs_f64();
        if std::env::var_os("A3PO_QUIET").is_none() {
            eprintln!(
                "[runtime] compiled {:<18} ({:>7.2} MB HLO) in {:.2}s",
                spec.name,
                spec.hlo_bytes as f64 / 1e6,
                dt
            );
        }
        Ok(Arc::new(Executable {
            exe: SendExec(exe),
            spec: spec.clone(),
            stats: std::sync::Mutex::new(ExecStats::default()),
        }))
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    /// Execute with pre-packed literals (fast path: callers that keep
    /// literals resident, e.g. the trainer's parameter state).
    pub fn run_literals(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest says {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let t0 = Instant::now();
        let result = self
            .exe
            .0
            .execute::<&Literal>(inputs)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", self.spec.name))?;
        let outs = tuple.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.spec.name,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        let mut s = self.stats.lock().unwrap();
        s.calls += 1;
        s.total_secs += t0.elapsed().as_secs_f64();
        Ok(outs)
    }

    /// Execute from host tensors (validates shapes against the manifest).
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest says {}",
                self.spec.name,
                inputs.len(),
                self.spec.inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.spec.inputs) {
            t.check(spec).with_context(|| format!("in {}", self.spec.name))?;
            lits.push(t.to_literal()?);
        }
        let refs: Vec<&Literal> = lits.iter().collect();
        let outs = self.run_literals(&refs)?;
        outs.iter()
            .zip(&self.spec.outputs)
            .map(|(l, spec)| HostTensor::from_literal(l, spec))
            .collect()
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.lock().unwrap()
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Executable({})", self.spec.name)
    }
}
