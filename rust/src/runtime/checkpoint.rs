//! Checkpointing: save/load parameter snapshots so benchmark evaluation
//! (Table 2) can run on a previously trained policy.
//!
//! Format: `<path>.json` — a JSON header with the param specs and version;
//! `<path>.bin` — the raw little-endian f32 data concatenated in manifest
//! order. Backend-independent: any snapshot of host tensors round-trips.
//!
//! A second variant (`a3po-opt-v1`) saves the full optimiser state
//! ([`TrainState`]: params + Adam moments + step counter) so a training run
//! can resume exactly: the `.bin` holds params, then first moments, then
//! second moments, each in manifest order.

use std::io::{Read, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::manifest::{Dtype, Manifest, TensorSpec};
use super::params::ParamSnapshot;
use super::tensor::HostTensor;
use super::train::TrainState;

pub fn save(path: &Path, manifest: &Manifest, snapshot: &ParamSnapshot) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut header = vec![
        ("format", Json::Str("a3po-ckpt-v1".into())),
        ("preset", Json::Str(manifest.preset.name.clone())),
        ("version", Json::Num(snapshot.version as f64)),
        (
            "params",
            Json::Arr(
                manifest
                    .params
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("name", Json::Str(s.name.clone())),
                            (
                                "shape",
                                Json::Arr(
                                    s.shape.iter().map(|&d| Json::Num(d as f64)).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    header.sort_by(|a, b| a.0.cmp(b.0));
    std::fs::write(path.with_extension("json"), Json::obj(header).dump())?;

    let mut bin = std::io::BufWriter::new(std::fs::File::create(path.with_extension("bin"))?);
    for (tensor, spec) in snapshot.params.iter().zip(&manifest.params) {
        tensor.check(spec).with_context(|| format!("saving param {}", spec.name))?;
        let data = tensor.as_f32()?;
        for x in data {
            bin.write_all(&x.to_le_bytes())?;
        }
    }
    bin.flush()?;
    Ok(())
}

pub fn load(path: &Path, manifest: &Manifest) -> Result<Arc<ParamSnapshot>> {
    let header_path = path.with_extension("json");
    let header = Json::parse(
        &std::fs::read_to_string(&header_path)
            .with_context(|| format!("reading {}", header_path.display()))?,
    )?;
    if header.get("format").as_str() != Some("a3po-ckpt-v1") {
        bail!("bad checkpoint format");
    }
    if header.get("preset").as_str() != Some(manifest.preset.name.as_str()) {
        bail!(
            "checkpoint is for preset {:?}, manifest is {:?}",
            header.get("preset"),
            manifest.preset.name
        );
    }
    let version = header.get("version").as_i64().unwrap_or(0) as u64;

    let mut f = std::io::BufReader::new(std::fs::File::open(path.with_extension("bin"))?);
    let mut params = Vec::with_capacity(manifest.params.len());
    for spec in &manifest.params {
        if spec.dtype != Dtype::F32 {
            bail!("checkpoint only supports f32 params");
        }
        let n = spec.elements();
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)
            .with_context(|| format!("reading param {}", spec.name))?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        params.push(HostTensor::f32(spec.shape.clone(), data));
    }
    // Trailing data means spec drift.
    let mut extra = [0u8; 1];
    if f.read(&mut extra)? != 0 {
        bail!("checkpoint has trailing data (param spec drift?)");
    }
    Ok(ParamSnapshot::new(version, params))
}

/// Save a full optimiser state (params + Adam moments + step counter) for
/// exact training resume.
pub fn save_train_state(path: &Path, manifest: &Manifest, state: &TrainState) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut header = vec![
        ("format", Json::Str("a3po-opt-v1".into())),
        ("preset", Json::Str(manifest.preset.name.clone())),
        ("opt_step", Json::Num(state.opt_step as f64)),
    ];
    header.sort_by(|a, b| a.0.cmp(b.0));
    std::fs::write(path.with_extension("json"), Json::obj(header).dump())?;

    let mut bin = std::io::BufWriter::new(std::fs::File::create(path.with_extension("bin"))?);
    for (label, group) in
        [("param", &state.params), ("adam_m", &state.adam_m), ("adam_v", &state.adam_v)]
    {
        if group.len() != manifest.params.len() {
            bail!("{label} group has {} tensors, manifest {}", group.len(), manifest.params.len());
        }
        for (tensor, spec) in group.iter().zip(&manifest.params) {
            tensor.check(spec).with_context(|| format!("saving {label} {}", spec.name))?;
            for x in tensor.as_f32()? {
                bin.write_all(&x.to_le_bytes())?;
            }
        }
    }
    bin.flush()?;
    Ok(())
}

/// Load a full optimiser state saved by [`save_train_state`].
pub fn load_train_state(path: &Path, manifest: &Manifest) -> Result<TrainState> {
    let header_path = path.with_extension("json");
    let header = Json::parse(
        &std::fs::read_to_string(&header_path)
            .with_context(|| format!("reading {}", header_path.display()))?,
    )?;
    if header.get("format").as_str() != Some("a3po-opt-v1") {
        bail!("bad train-state format (expected a3po-opt-v1)");
    }
    if header.get("preset").as_str() != Some(manifest.preset.name.as_str()) {
        bail!(
            "train state is for preset {:?}, manifest is {:?}",
            header.get("preset"),
            manifest.preset.name
        );
    }
    let opt_step = header.get("opt_step").as_i64().unwrap_or(0) as i32;

    let mut f = std::io::BufReader::new(std::fs::File::open(path.with_extension("bin"))?);
    let mut read_group = |label: &str| -> Result<Vec<HostTensor>> {
        let mut group = Vec::with_capacity(manifest.params.len());
        for spec in &manifest.params {
            if spec.dtype != Dtype::F32 {
                bail!("train state only supports f32 params");
            }
            let n = spec.elements();
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)
                .with_context(|| format!("reading {label} {}", spec.name))?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            group.push(HostTensor::f32(spec.shape.clone(), data));
        }
        Ok(group)
    };
    let params = read_group("param")?;
    let adam_m = read_group("adam_m")?;
    let adam_v = read_group("adam_v")?;
    let mut extra = [0u8; 1];
    if f.read(&mut extra)? != 0 {
        bail!("train state has trailing data (param spec drift?)");
    }
    Ok(TrainState { opt_step, params, adam_m, adam_v })
}

/// Sanity helper for tests: total f32 elements a checkpoint should hold.
pub fn expected_elements(specs: &[TensorSpec]) -> usize {
    specs.iter().map(|s| s.elements()).sum()
}
