//! Token sampling from decode logits.
//!
//! This is the inference-engine half of the behaviour policy contract: like
//! SGLang/vLLM in the paper's stack, the sampler returns both the sampled
//! token and its log-probability under the behaviour policy — the
//! `behav_logp` consumed by the decoupled loss. Paper settings: temperature
//! 1.0, top-p 1.0, top-k = full vocabulary (all supported here, plus greedy
//! for deterministic eval).

use crate::util::rng::Pcg64;

#[derive(Debug, Clone, Copy)]
pub struct SamplerConfig {
    pub temperature: f64,
    pub top_p: f64,
    /// 0 = full vocabulary.
    pub top_k: usize,
    pub greedy: bool,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig { temperature: 1.0, top_p: 1.0, top_k: 0, greedy: false }
    }
}

impl SamplerConfig {
    pub fn greedy() -> Self {
        SamplerConfig { greedy: true, ..Default::default() }
    }
}

/// Sample one token from a logit row. Returns `(token, logp)` where `logp`
/// is the log-probability of the sampled token under the *unmodified*
/// temperature-scaled distribution (what the training loss needs — top-p/k
/// truncation affects which token is drawn, not the reported logp, matching
/// how inference engines report `logprobs`).
pub fn sample(logits: &[f32], cfg: &SamplerConfig, rng: &mut Pcg64) -> (i32, f32) {
    assert!(!logits.is_empty());
    let logp = log_softmax(logits, cfg.temperature);

    let token = if cfg.greedy {
        argmax(&logp)
    } else {
        let mut idx: Vec<usize> = (0..logp.len()).collect();
        // Restrict to top-k / top-p nucleus if configured.
        if cfg.top_k > 0 || cfg.top_p < 1.0 {
            idx.sort_by(|&a, &b| logp[b].partial_cmp(&logp[a]).unwrap());
            if cfg.top_k > 0 && cfg.top_k < idx.len() {
                idx.truncate(cfg.top_k);
            }
            if cfg.top_p < 1.0 {
                let mut cum = 0.0f64;
                let mut keep = 0;
                for &i in &idx {
                    cum += (logp[i] as f64).exp();
                    keep += 1;
                    if cum >= cfg.top_p {
                        break;
                    }
                }
                idx.truncate(keep.max(1));
            }
        }
        let weights: Vec<f32> = idx.iter().map(|&i| logp[i].exp()).collect();
        idx[rng.categorical(&weights)]
    };
    (token as i32, logp[token])
}

/// Numerically-stable log-softmax with temperature.
pub fn log_softmax(logits: &[f32], temperature: f64) -> Vec<f32> {
    let t = temperature.max(1e-6) as f32;
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b / t));
    let mut out: Vec<f32> = logits.iter().map(|&z| z / t - m).collect();
    let lse = out.iter().map(|&x| x.exp()).sum::<f32>().ln();
    for x in &mut out {
        *x -= lse;
    }
    out
}

fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalises() {
        let lp = log_softmax(&[1.0, 2.0, 3.0], 1.0);
        let total: f32 = lp.iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert!(lp[2] > lp[1] && lp[1] > lp[0]);
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Pcg64::from_seed(1);
        let (tok, lp) = sample(&[0.1, 5.0, -1.0], &SamplerConfig::greedy(), &mut rng);
        assert_eq!(tok, 1);
        assert!(lp < 0.0);
    }

    #[test]
    fn sampling_frequencies_track_probs() {
        let mut rng = Pcg64::from_seed(2);
        let logits = [0.0f32, (4.0f32).ln(), f32::NEG_INFINITY];
        let cfg = SamplerConfig::default();
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[sample(&logits, &cfg, &mut rng).0 as usize] += 1;
        }
        assert_eq!(counts[2], 0);
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((ratio - 4.0).abs() < 0.8, "ratio={ratio}");
    }

    #[test]
    fn top_k_truncates() {
        let mut rng = Pcg64::from_seed(3);
        let logits = [3.0f32, 2.0, -10.0, -10.0];
        let cfg = SamplerConfig { top_k: 2, ..Default::default() };
        for _ in 0..200 {
            let (tok, _) = sample(&logits, &cfg, &mut rng);
            assert!(tok == 0 || tok == 1, "tok={tok}");
        }
    }

    #[test]
    fn top_p_keeps_nucleus() {
        let mut rng = Pcg64::from_seed(4);
        // p(0) ~ 0.84; top_p=0.5 nucleus = {0} only.
        let logits = [2.0f32, 0.0, 0.0, 0.0];
        let cfg = SamplerConfig { top_p: 0.5, ..Default::default() };
        for _ in 0..100 {
            assert_eq!(sample(&logits, &cfg, &mut rng).0, 0);
        }
    }

    #[test]
    fn temperature_sharpens() {
        let lp_hot = log_softmax(&[1.0, 2.0], 2.0);
        let lp_cold = log_softmax(&[1.0, 2.0], 0.5);
        // Colder temperature concentrates mass on the max.
        assert!(lp_cold[1].exp() > lp_hot[1].exp());
    }

    #[test]
    fn reported_logp_matches_full_distribution() {
        // Even with top-k truncation the reported logp is from the full
        // distribution (inference-engine contract).
        let mut rng = Pcg64::from_seed(5);
        let logits = [1.0f32, 0.5, 0.0];
        let full = log_softmax(&logits, 1.0);
        let cfg = SamplerConfig { top_k: 1, ..Default::default() };
        let (tok, lp) = sample(&logits, &cfg, &mut rng);
        assert_eq!(tok, 0);
        assert!((lp - full[0]).abs() < 1e-6);
    }
}
