//! Staleness-tagged episode buffer — the decoupling point between the
//! rollout engine and the trainer (the asynchronous-RL heart of the paper's
//! setup, AReaL-style).
//!
//! * Episodes arrive in complete GRPO *groups* (all `G` responses to one
//!   prompt), each tagged with the behaviour-policy version that generated
//!   it.
//! * `pop_groups` serves the oldest admissible groups, dropping any whose
//!   staleness `d = v_now - v_behav` exceeds `max_staleness` (the paper's
//!   staleness control).
//! * `push_group` applies backpressure: rollout workers block while the
//!   buffer holds `max_buffered` or more episodes, so generation can never
//!   run unboundedly ahead of training.
//!
//! The buffer also carries the pipeline's occupancy telemetry: a cached
//! episode count (O(1) backpressure checks instead of a deque rescan under
//! the lock), a decimated occupancy time series with a high-water mark, and
//! blocked-wait accounting on both sides (`push_wait_ns`/`pop_wait_ns`),
//! surfaced through [`EpisodeBuffer::telemetry`] and, when tracing is on,
//! as `buffer_push_wait`/`buffer_pop_wait` spans plus a `buffer_episodes`
//! counter track.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::config::StalenessPolicy;
use crate::env::Problem;
use crate::trace;
use crate::trace::report::BufferTelemetry;

/// One generated response with everything the trainer needs.
#[derive(Debug, Clone)]
pub struct Episode {
    /// Full padded token window `[seq_len]` (prompt + generation).
    pub tokens: Vec<i32>,
    /// Behaviour-policy log-prob per next-token position `[seq_len - 1]`;
    /// zero outside the generated region.
    pub behav_logp: Vec<f32>,
    /// Loss mask per next-token position `[seq_len - 1]` (1.0 on generated
    /// tokens including EOS).
    pub mask: Vec<f32>,
    /// Shaped training reward (see env::verifier).
    pub reward: f64,
    /// Strict exact-match reward (reported in figures/tables).
    pub reward_exact: f64,
    /// Behaviour-policy version `v(pi_behav)`.
    pub version: u64,
    /// GRPO group id (all responses to one prompt share it).
    pub group: u64,
    /// Decoded generation (diagnostics).
    pub text: String,
    pub problem: Problem,
}

impl Episode {
    pub fn staleness(&self, v_now: u64) -> u64 {
        v_now.saturating_sub(self.version)
    }
}

#[derive(Debug, Default)]
pub struct BufferStats {
    pub pushed_groups: AtomicU64,
    pub popped_groups: AtomicU64,
    pub dropped_stale_groups: AtomicU64,
    /// Total nanoseconds rollout workers spent blocked on backpressure in
    /// `push_group`.
    pub push_wait_ns: AtomicU64,
    /// Total nanoseconds the trainer spent blocked in `pop_groups`.
    pub pop_wait_ns: AtomicU64,
    /// Max episodes ever simultaneously buffered.
    pub high_water_episodes: AtomicU64,
}

/// Occupancy-series length cap; on overflow every other sample is dropped
/// and the sampling stride doubles, so memory stays bounded on long runs.
const OCCUPANCY_CAP: usize = 4096;

#[derive(Debug)]
struct Inner {
    q: VecDeque<Vec<Episode>>,
    /// Cached `sum of group lens` — kept in sync on push/pop/drop/restore
    /// so backpressure checks and occupancy sampling are O(1).
    episodes: usize,
    /// Decimated `(secs since buffer creation, buffered episodes)` series.
    series: Vec<(f64, u64)>,
    /// Record every `stride`-th occupancy change once the series fills.
    stride: u64,
    ticks: u64,
}

impl Inner {
    fn new() -> Inner {
        Inner { q: VecDeque::new(), episodes: 0, series: Vec::new(), stride: 1, ticks: 0 }
    }

    fn debug_check(&self) {
        debug_assert_eq!(
            self.episodes,
            self.q.iter().map(|g| g.len()).sum::<usize>(),
            "cached episode count drifted from deque contents"
        );
    }

    fn sample_occupancy(&mut self, t_secs: f64) {
        self.ticks += 1;
        if self.ticks % self.stride != 0 {
            return;
        }
        if self.series.len() >= OCCUPANCY_CAP {
            let mut keep = false;
            self.series.retain(|_| {
                keep = !keep;
                keep
            });
            self.stride = self.stride.saturating_mul(2);
        }
        self.series.push((t_secs, self.episodes as u64));
    }
}

#[derive(Debug)]
pub struct EpisodeBuffer {
    inner: Mutex<Inner>,
    /// Signalled when groups are added or space frees up or shutdown.
    cond: Condvar,
    policy: StalenessPolicy,
    shutdown: AtomicBool,
    /// Creation time; occupancy samples are relative to this.
    born: Instant,
    pub stats: BufferStats,
}

impl EpisodeBuffer {
    pub fn new(policy: StalenessPolicy) -> Self {
        EpisodeBuffer {
            inner: Mutex::new(Inner::new()),
            cond: Condvar::new(),
            policy,
            shutdown: AtomicBool::new(false),
            born: Instant::now(),
            stats: BufferStats::default(),
        }
    }

    pub fn len_episodes(&self) -> usize {
        self.inner.lock().unwrap().episodes
    }

    pub fn len_groups(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    /// Record occupancy + high-water after a mutation (lock held by caller).
    fn note_occupancy(&self, inner: &mut MutexGuard<'_, Inner>) {
        inner.debug_check();
        let n = inner.episodes as u64;
        self.stats.high_water_episodes.fetch_max(n, Ordering::Relaxed);
        let t = self.born.elapsed().as_secs_f64();
        inner.sample_occupancy(t);
        trace::counter("buffer_episodes", n as f64);
    }

    /// Account a blocked wait that started at `since` (counter + span).
    fn note_wait(&self, counter: &AtomicU64, since: Instant, span_name: &'static str) {
        let waited = since.elapsed();
        counter.fetch_add(waited.as_nanos() as u64, Ordering::Relaxed);
        if trace::enabled() {
            let end = trace::now_us();
            trace::complete_span(span_name, "buffer", end - waited.as_secs_f64() * 1e6, end, None);
        }
    }

    /// Blocks while the buffer is at capacity (backpressure). Returns false
    /// if the buffer is shut down (caller should exit).
    pub fn push_group(&self, group: Vec<Episode>) -> bool {
        assert!(!group.is_empty());
        let entered = Instant::now();
        let mut blocked = false;
        let mut q = self.inner.lock().unwrap();
        loop {
            if self.shutdown.load(Ordering::Acquire) {
                if blocked {
                    self.note_wait(&self.stats.push_wait_ns, entered, "buffer_push_wait");
                }
                return false;
            }
            if q.episodes < self.policy.max_buffered {
                break;
            }
            blocked = true;
            q = self.cond.wait(q).unwrap_or_else(|e| e.into_inner());
        }
        if blocked {
            self.note_wait(&self.stats.push_wait_ns, entered, "buffer_push_wait");
        }
        q.episodes += group.len();
        q.q.push_back(group);
        self.note_occupancy(&mut q);
        self.stats.pushed_groups.fetch_add(1, Ordering::Relaxed);
        self.cond.notify_all();
        true
    }

    /// Pop `n` admissible groups, blocking until available. Groups staler
    /// than the policy allows (relative to `v_now`) are discarded and
    /// counted. Returns None on shutdown (restoring any partially drained
    /// groups so shutdown-time accounting still balances).
    pub fn pop_groups(&self, n: usize, v_now: u64) -> Option<Vec<Vec<Episode>>> {
        let entered = Instant::now();
        let mut blocked = false;
        let mut out = Vec::with_capacity(n);
        let mut q = self.inner.lock().unwrap();
        loop {
            // Drain admissible groups from the front.
            let mut mutated = false;
            while out.len() < n {
                match q.q.pop_front() {
                    None => break,
                    Some(g) => {
                        q.episodes -= g.len();
                        mutated = true;
                        let d = g[0].staleness(v_now);
                        if d > self.policy.max_staleness {
                            self.stats.dropped_stale_groups.fetch_add(1, Ordering::Relaxed);
                            // freed capacity: wake pushers
                            self.cond.notify_all();
                        } else {
                            out.push(g);
                        }
                    }
                }
            }
            if mutated {
                self.note_occupancy(&mut q);
            }
            if out.len() == n {
                self.stats.popped_groups.fetch_add(n as u64, Ordering::Relaxed);
                self.cond.notify_all();
                if blocked {
                    self.note_wait(&self.stats.pop_wait_ns, entered, "buffer_pop_wait");
                }
                return Some(out);
            }
            if self.shutdown.load(Ordering::Acquire) {
                // Put partial results back (front, preserving order) so the
                // pushed == popped + dropped + remaining identity holds.
                for g in out.into_iter().rev() {
                    q.episodes += g.len();
                    q.q.push_front(g);
                }
                q.debug_check();
                if blocked {
                    self.note_wait(&self.stats.pop_wait_ns, entered, "buffer_pop_wait");
                }
                return None;
            }
            blocked = true;
            q = self.cond.wait(q).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking variant used by tests and the sync path.
    pub fn try_pop_groups(&self, n: usize, v_now: u64) -> Option<Vec<Vec<Episode>>> {
        let mut out = Vec::with_capacity(n);
        let mut q = self.inner.lock().unwrap();
        let mut mutated = false;
        while out.len() < n {
            match q.q.pop_front() {
                None => break,
                Some(g) => {
                    q.episodes -= g.len();
                    mutated = true;
                    let d = g[0].staleness(v_now);
                    if d > self.policy.max_staleness {
                        self.stats.dropped_stale_groups.fetch_add(1, Ordering::Relaxed);
                        // A discarded group frees capacity: wake any rollout
                        // worker blocked in `push_group`, even if this pop
                        // ends up returning None.
                        self.cond.notify_all();
                    } else {
                        out.push(g);
                    }
                }
            }
        }
        if out.len() == n {
            if mutated {
                self.note_occupancy(&mut q);
            }
            self.stats.popped_groups.fetch_add(n as u64, Ordering::Relaxed);
            self.cond.notify_all();
            Some(out)
        } else {
            // Put partial results back (front, preserving order).
            for g in out.into_iter().rev() {
                q.episodes += g.len();
                q.q.push_front(g);
            }
            if mutated {
                // Stale drops may still have changed the count.
                self.note_occupancy(&mut q);
            }
            None
        }
    }

    /// Aggregate buffer telemetry snapshot (counters + occupancy series).
    pub fn telemetry(&self) -> BufferTelemetry {
        let inner = self.inner.lock().unwrap();
        BufferTelemetry {
            pushed_groups: self.stats.pushed_groups.load(Ordering::Relaxed),
            popped_groups: self.stats.popped_groups.load(Ordering::Relaxed),
            dropped_stale_groups: self.stats.dropped_stale_groups.load(Ordering::Relaxed),
            remaining_groups: inner.q.len() as u64,
            push_wait_secs: self.stats.push_wait_ns.load(Ordering::Relaxed) as f64 / 1e9,
            pop_wait_secs: self.stats.pop_wait_ns.load(Ordering::Relaxed) as f64 / 1e9,
            high_water_episodes: self.stats.high_water_episodes.load(Ordering::Relaxed),
            occupancy: inner.series.clone(),
        }
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.cond.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn ep(version: u64, group: u64) -> Episode {
        Episode {
            tokens: vec![0; 4],
            behav_logp: vec![0.0; 3],
            mask: vec![1.0; 3],
            reward: 0.0,
            reward_exact: 0.0,
            version,
            group,
            text: String::new(),
            problem: Problem { prompt: "1+1=".into(), answer: "2".into() },
        }
    }

    fn buffer(max_staleness: u64, max_buffered: usize) -> EpisodeBuffer {
        EpisodeBuffer::new(StalenessPolicy { max_staleness, max_buffered })
    }

    #[test]
    fn fifo_order() {
        let b = buffer(10, 100);
        b.push_group(vec![ep(0, 1)]);
        b.push_group(vec![ep(0, 2)]);
        let got = b.try_pop_groups(2, 0).unwrap();
        assert_eq!(got[0][0].group, 1);
        assert_eq!(got[1][0].group, 2);
    }

    #[test]
    fn drops_stale_groups() {
        let b = buffer(2, 100);
        b.push_group(vec![ep(0, 1)]); // staleness 5 at v=5 -> dropped
        b.push_group(vec![ep(4, 2)]); // staleness 1 -> kept
        let got = b.try_pop_groups(1, 5).unwrap();
        assert_eq!(got[0][0].group, 2);
        assert_eq!(b.stats.dropped_stale_groups.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn try_pop_insufficient_restores() {
        let b = buffer(10, 100);
        b.push_group(vec![ep(0, 1)]);
        assert!(b.try_pop_groups(2, 0).is_none());
        assert_eq!(b.len_groups(), 1, "partial pop must restore");
        assert!(b.try_pop_groups(1, 0).is_some());
    }

    #[test]
    fn backpressure_blocks_then_releases() {
        let b = Arc::new(buffer(10, 2));
        b.push_group(vec![ep(0, 1), ep(0, 1)]); // buffer full (2 episodes)
        let b2 = b.clone();
        let pusher = std::thread::spawn(move || b2.push_group(vec![ep(0, 2)]));
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!pusher.is_finished(), "push should block at capacity");
        b.pop_groups(1, 0).unwrap();
        assert!(pusher.join().unwrap());
        assert_eq!(b.len_groups(), 1);
    }

    #[test]
    fn dropping_stale_groups_wakes_blocked_pushers() {
        // Regression: try_pop_groups used to notify only on the success
        // path, so a pusher blocked on capacity could sleep forever after
        // stale groups were discarded (freeing space) by a failed pop.
        let b = Arc::new(buffer(1, 2));
        b.push_group(vec![ep(0, 1)]);
        b.push_group(vec![ep(0, 2)]); // buffer full (2 episodes)
        let b2 = b.clone();
        let pusher = std::thread::spawn(move || b2.push_group(vec![ep(10, 3)]));
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!pusher.is_finished(), "push should block at capacity");
        // Both buffered groups are overstale at v=10 -> dropped; the pop
        // itself comes up empty-handed (None) but must still wake pushers.
        assert!(b.try_pop_groups(1, 10).is_none());
        assert!(pusher.join().unwrap());
        assert_eq!(b.len_groups(), 1);
        assert_eq!(b.stats.dropped_stale_groups.load(Ordering::Relaxed), 2);
        // The fresh group is now serveable.
        assert_eq!(b.try_pop_groups(1, 10).unwrap()[0][0].group, 3);
    }

    #[test]
    fn shutdown_unblocks_everyone() {
        let b = Arc::new(buffer(10, 1));
        let b2 = b.clone();
        let popper = std::thread::spawn(move || b2.pop_groups(1, 0));
        std::thread::sleep(std::time::Duration::from_millis(30));
        b.shutdown();
        assert!(popper.join().unwrap().is_none());
        assert!(!b.push_group(vec![ep(0, 1)]));
    }

    #[test]
    fn staleness_computation_saturates() {
        let e = ep(7, 0);
        assert_eq!(e.staleness(7), 0);
        assert_eq!(e.staleness(9), 2);
        assert_eq!(e.staleness(3), 0, "future versions clamp to 0");
    }

    #[test]
    fn cached_episode_count_tracks_mutations() {
        let b = buffer(2, 100);
        b.push_group(vec![ep(0, 1), ep(0, 1)]);
        b.push_group(vec![ep(0, 2)]);
        assert_eq!(b.len_episodes(), 3);
        // Failed try_pop restores the drained groups and the count.
        assert!(b.try_pop_groups(3, 0).is_none());
        assert_eq!(b.len_episodes(), 3);
        // Stale drops at v=10 (staleness 10 > 2) reduce the count too.
        b.push_group(vec![ep(10, 3)]);
        let got = b.try_pop_groups(1, 10).unwrap();
        assert_eq!(got[0][0].group, 3);
        assert_eq!(b.len_episodes(), 0);
        assert_eq!(b.stats.dropped_stale_groups.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn occupancy_and_high_water_populate() {
        let b = buffer(10, 100);
        b.push_group(vec![ep(0, 1), ep(0, 1)]);
        b.push_group(vec![ep(0, 2)]);
        b.try_pop_groups(2, 0).unwrap();
        let t = b.telemetry();
        assert_eq!(t.high_water_episodes, 3);
        assert_eq!(t.occupancy.len(), 3, "one sample per mutation at stride 1");
        assert_eq!(t.occupancy.last().unwrap().1, 0);
        assert!(t.accounting_consistent());
        assert_eq!(t.pushed_groups, 2);
        assert_eq!(t.popped_groups, 2);
    }

    #[test]
    fn push_wait_time_recorded_under_backpressure() {
        let b = Arc::new(buffer(10, 1));
        b.push_group(vec![ep(0, 1)]);
        let b2 = b.clone();
        let pusher = std::thread::spawn(move || b2.push_group(vec![ep(0, 2)]));
        std::thread::sleep(std::time::Duration::from_millis(40));
        b.pop_groups(1, 0).unwrap();
        assert!(pusher.join().unwrap());
        let waited_ns = b.stats.push_wait_ns.load(Ordering::Relaxed);
        assert!(waited_ns >= 10_000_000, "blocked push must account its wait, got {waited_ns}ns");
        let pop_ns = b.stats.pop_wait_ns.load(Ordering::Relaxed);
        assert_eq!(pop_ns, 0, "non-blocked pop records no wait");
    }

    #[test]
    fn shutdown_restores_partially_drained_groups() {
        let b = buffer(10, 100);
        b.push_group(vec![ep(0, 1)]);
        b.shutdown();
        assert!(b.pop_groups(2, 0).is_none());
        assert_eq!(b.len_groups(), 1, "partial blocking pop must restore on shutdown");
        assert!(b.telemetry().accounting_consistent());
    }
}
