//! Staleness-tagged episode buffer — the decoupling point between the
//! rollout engine and the trainer (the asynchronous-RL heart of the paper's
//! setup, AReaL-style).
//!
//! * Episodes arrive in complete GRPO *groups* (all `G` responses to one
//!   prompt), each tagged with the behaviour-policy version that generated
//!   it.
//! * `pop_groups` serves the oldest admissible groups, dropping any whose
//!   staleness `d = v_now - v_behav` exceeds `max_staleness` (the paper's
//!   staleness control).
//! * `push_group` applies backpressure: rollout workers block while the
//!   buffer holds `max_buffered` or more episodes, so generation can never
//!   run unboundedly ahead of training.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use crate::config::StalenessPolicy;
use crate::env::Problem;

/// One generated response with everything the trainer needs.
#[derive(Debug, Clone)]
pub struct Episode {
    /// Full padded token window `[seq_len]` (prompt + generation).
    pub tokens: Vec<i32>,
    /// Behaviour-policy log-prob per next-token position `[seq_len - 1]`;
    /// zero outside the generated region.
    pub behav_logp: Vec<f32>,
    /// Loss mask per next-token position `[seq_len - 1]` (1.0 on generated
    /// tokens including EOS).
    pub mask: Vec<f32>,
    /// Shaped training reward (see env::verifier).
    pub reward: f64,
    /// Strict exact-match reward (reported in figures/tables).
    pub reward_exact: f64,
    /// Behaviour-policy version `v(pi_behav)`.
    pub version: u64,
    /// GRPO group id (all responses to one prompt share it).
    pub group: u64,
    /// Decoded generation (diagnostics).
    pub text: String,
    pub problem: Problem,
}

impl Episode {
    pub fn staleness(&self, v_now: u64) -> u64 {
        v_now.saturating_sub(self.version)
    }
}

#[derive(Debug, Default)]
pub struct BufferStats {
    pub pushed_groups: AtomicU64,
    pub popped_groups: AtomicU64,
    pub dropped_stale_groups: AtomicU64,
}

#[derive(Debug)]
pub struct EpisodeBuffer {
    inner: Mutex<VecDeque<Vec<Episode>>>,
    /// Signalled when groups are added or space frees up or shutdown.
    cond: Condvar,
    policy: StalenessPolicy,
    shutdown: AtomicBool,
    pub stats: BufferStats,
}

impl EpisodeBuffer {
    pub fn new(policy: StalenessPolicy) -> Self {
        EpisodeBuffer {
            inner: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            policy,
            shutdown: AtomicBool::new(false),
            stats: BufferStats::default(),
        }
    }

    pub fn len_episodes(&self) -> usize {
        self.inner.lock().unwrap().iter().map(|g| g.len()).sum()
    }

    pub fn len_groups(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// Blocks while the buffer is at capacity (backpressure). Returns false
    /// if the buffer is shut down (caller should exit).
    pub fn push_group(&self, group: Vec<Episode>) -> bool {
        assert!(!group.is_empty());
        let mut q = self.inner.lock().unwrap();
        loop {
            if self.shutdown.load(Ordering::Acquire) {
                return false;
            }
            let buffered: usize = q.iter().map(|g| g.len()).sum();
            if buffered < self.policy.max_buffered {
                break;
            }
            q = self.cond.wait(q).unwrap_or_else(|e| e.into_inner());
        }
        q.push_back(group);
        self.stats.pushed_groups.fetch_add(1, Ordering::Relaxed);
        self.cond.notify_all();
        true
    }

    /// Pop `n` admissible groups, blocking until available. Groups staler
    /// than the policy allows (relative to `v_now`) are discarded and
    /// counted. Returns None on shutdown.
    pub fn pop_groups(&self, n: usize, v_now: u64) -> Option<Vec<Vec<Episode>>> {
        let mut out = Vec::with_capacity(n);
        let mut q = self.inner.lock().unwrap();
        loop {
            // Drain admissible groups from the front.
            while out.len() < n {
                match q.pop_front() {
                    None => break,
                    Some(g) => {
                        let d = g[0].staleness(v_now);
                        if d > self.policy.max_staleness {
                            self.stats.dropped_stale_groups.fetch_add(1, Ordering::Relaxed);
                            // freed capacity: wake pushers
                            self.cond.notify_all();
                        } else {
                            out.push(g);
                        }
                    }
                }
            }
            if out.len() == n {
                self.stats.popped_groups.fetch_add(n as u64, Ordering::Relaxed);
                self.cond.notify_all();
                return Some(out);
            }
            if self.shutdown.load(Ordering::Acquire) {
                return None;
            }
            q = self.cond.wait(q).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking variant used by tests and the sync path.
    pub fn try_pop_groups(&self, n: usize, v_now: u64) -> Option<Vec<Vec<Episode>>> {
        let mut out = Vec::with_capacity(n);
        let mut q = self.inner.lock().unwrap();
        while out.len() < n {
            match q.pop_front() {
                None => break,
                Some(g) => {
                    let d = g[0].staleness(v_now);
                    if d > self.policy.max_staleness {
                        self.stats.dropped_stale_groups.fetch_add(1, Ordering::Relaxed);
                        // A discarded group frees capacity: wake any rollout
                        // worker blocked in `push_group`, even if this pop
                        // ends up returning None.
                        self.cond.notify_all();
                    } else {
                        out.push(g);
                    }
                }
            }
        }
        if out.len() == n {
            self.stats.popped_groups.fetch_add(n as u64, Ordering::Relaxed);
            self.cond.notify_all();
            Some(out)
        } else {
            // Put partial results back (front, preserving order).
            for g in out.into_iter().rev() {
                q.push_front(g);
            }
            None
        }
    }

    pub fn shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        self.cond.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn ep(version: u64, group: u64) -> Episode {
        Episode {
            tokens: vec![0; 4],
            behav_logp: vec![0.0; 3],
            mask: vec![1.0; 3],
            reward: 0.0,
            reward_exact: 0.0,
            version,
            group,
            text: String::new(),
            problem: Problem { prompt: "1+1=".into(), answer: "2".into() },
        }
    }

    fn buffer(max_staleness: u64, max_buffered: usize) -> EpisodeBuffer {
        EpisodeBuffer::new(StalenessPolicy { max_staleness, max_buffered })
    }

    #[test]
    fn fifo_order() {
        let b = buffer(10, 100);
        b.push_group(vec![ep(0, 1)]);
        b.push_group(vec![ep(0, 2)]);
        let got = b.try_pop_groups(2, 0).unwrap();
        assert_eq!(got[0][0].group, 1);
        assert_eq!(got[1][0].group, 2);
    }

    #[test]
    fn drops_stale_groups() {
        let b = buffer(2, 100);
        b.push_group(vec![ep(0, 1)]); // staleness 5 at v=5 -> dropped
        b.push_group(vec![ep(4, 2)]); // staleness 1 -> kept
        let got = b.try_pop_groups(1, 5).unwrap();
        assert_eq!(got[0][0].group, 2);
        assert_eq!(b.stats.dropped_stale_groups.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn try_pop_insufficient_restores() {
        let b = buffer(10, 100);
        b.push_group(vec![ep(0, 1)]);
        assert!(b.try_pop_groups(2, 0).is_none());
        assert_eq!(b.len_groups(), 1, "partial pop must restore");
        assert!(b.try_pop_groups(1, 0).is_some());
    }

    #[test]
    fn backpressure_blocks_then_releases() {
        let b = Arc::new(buffer(10, 2));
        b.push_group(vec![ep(0, 1), ep(0, 1)]); // buffer full (2 episodes)
        let b2 = b.clone();
        let pusher = std::thread::spawn(move || b2.push_group(vec![ep(0, 2)]));
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!pusher.is_finished(), "push should block at capacity");
        b.pop_groups(1, 0).unwrap();
        assert!(pusher.join().unwrap());
        assert_eq!(b.len_groups(), 1);
    }

    #[test]
    fn dropping_stale_groups_wakes_blocked_pushers() {
        // Regression: try_pop_groups used to notify only on the success
        // path, so a pusher blocked on capacity could sleep forever after
        // stale groups were discarded (freeing space) by a failed pop.
        let b = Arc::new(buffer(1, 2));
        b.push_group(vec![ep(0, 1)]);
        b.push_group(vec![ep(0, 2)]); // buffer full (2 episodes)
        let b2 = b.clone();
        let pusher = std::thread::spawn(move || b2.push_group(vec![ep(10, 3)]));
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(!pusher.is_finished(), "push should block at capacity");
        // Both buffered groups are overstale at v=10 -> dropped; the pop
        // itself comes up empty-handed (None) but must still wake pushers.
        assert!(b.try_pop_groups(1, 10).is_none());
        assert!(pusher.join().unwrap());
        assert_eq!(b.len_groups(), 1);
        assert_eq!(b.stats.dropped_stale_groups.load(Ordering::Relaxed), 2);
        // The fresh group is now serveable.
        assert_eq!(b.try_pop_groups(1, 10).unwrap()[0][0].group, 3);
    }

    #[test]
    fn shutdown_unblocks_everyone() {
        let b = Arc::new(buffer(10, 1));
        let b2 = b.clone();
        let popper = std::thread::spawn(move || b2.pop_groups(1, 0));
        std::thread::sleep(std::time::Duration::from_millis(30));
        b.shutdown();
        assert!(popper.join().unwrap().is_none());
        assert!(!b.push_group(vec![ep(0, 1)]));
    }

    #[test]
    fn staleness_computation_saturates() {
        let e = ep(7, 0);
        assert_eq!(e.staleness(7), 0);
        assert_eq!(e.staleness(9), 2);
        assert_eq!(e.staleness(3), 0, "future versions clamp to 0");
    }
}
