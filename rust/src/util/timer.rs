//! Wall-clock timing helpers shared by the trainer (Fig. 1/Table 1 timing),
//! the metrics logger, and the bench harness.

use std::time::{Duration, Instant};

/// Scoped stopwatch.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Accumulates named phase durations (e.g. rollout / prox / train / publish)
/// across a run; powers the Fig. 1 and §Perf breakdowns.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    phases: Vec<(String, f64, u64)>, // (name, total seconds, count)
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.phases.iter_mut().find(|(n, _, _)| n == name) {
            e.1 += secs;
            e.2 += 1;
        } else {
            self.phases.push((name.to_string(), secs, 1));
        }
    }

    /// Time a closure under a phase name, returning its output.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let sw = Stopwatch::start();
        let out = f();
        self.add(name, sw.secs());
        out
    }

    pub fn total(&self, name: &str) -> f64 {
        self.phases.iter().find(|(n, _, _)| n == name).map(|e| e.1).unwrap_or(0.0)
    }

    pub fn count(&self, name: &str) -> u64 {
        self.phases.iter().find(|(n, _, _)| n == name).map(|e| e.2).unwrap_or(0)
    }

    pub fn mean(&self, name: &str) -> f64 {
        let c = self.count(name);
        if c == 0 {
            0.0
        } else {
            self.total(name) / c as f64
        }
    }

    pub fn report(&self) -> String {
        let mut s = String::from("phase                 total(s)   count   mean(ms)\n");
        for (name, total, count) in &self.phases {
            s.push_str(&format!(
                "{:<20} {:>9.3} {:>7} {:>10.3}\n",
                name,
                total,
                count,
                1e3 * total / *count as f64
            ));
        }
        s
    }

    pub fn phases(&self) -> impl Iterator<Item = (&str, f64, u64)> {
        self.phases.iter().map(|(n, t, c)| (n.as_str(), *t, *c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_accumulates() {
        let mut pt = PhaseTimer::new();
        pt.add("a", 1.0);
        pt.add("a", 2.0);
        pt.add("b", 0.5);
        assert_eq!(pt.total("a"), 3.0);
        assert_eq!(pt.count("a"), 2);
        assert_eq!(pt.mean("a"), 1.5);
        assert_eq!(pt.total("missing"), 0.0);
        assert!(pt.report().contains("a"));
    }

    #[test]
    fn time_closure_returns_value() {
        let mut pt = PhaseTimer::new();
        let v = pt.time("work", || 42);
        assert_eq!(v, 42);
        assert_eq!(pt.count("work"), 1);
    }
}
