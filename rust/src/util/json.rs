//! Minimal JSON parser/serialiser.
//!
//! The offline crate universe for this build does not include `serde`, so
//! the repo ships its own JSON substrate. It supports the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bools, null) and is used
//! for the artifact manifest, run configs, and metrics output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialisation is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup; returns `Json::Null` out of range.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- builders ------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    /// Compact single-line serialisation.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{}", n));
                    }
                } else {
                    // JSON has no inf/nan; emit null (documented lossy case).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                let combined =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(combined)
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad unicode escape"))?);
                            continue;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert!(v.get("a").at(2).get("b").is_null());
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn escapes_roundtrip() {
        let s = Json::Str("quote\" slash\\ nl\n tab\t".into());
        let parsed = Json::parse(&s.dump()).unwrap();
        assert_eq!(parsed, s);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        // surrogate pair: U+1F600
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("\u{1F600}".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn dump_roundtrip() {
        let text = r#"{"arr":[1,2.5,true,null],"name":"a3po","nested":{"k":-3}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }
}
