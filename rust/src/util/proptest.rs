//! Mini property-testing harness (the offline crate set has no proptest).
//!
//! Provides seeded random case generation with bounded shrinking: when a
//! property fails, the harness re-runs the property on progressively
//! "smaller" inputs derived by the `Shrink` implementation and reports the
//! smallest failure found. Used by `rust/tests/prop_*.rs` for coordinator
//! and substrate invariants.

use super::rng::Pcg64;

/// Number of random cases per property (override with A3PO_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("A3PO_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(128)
}

/// A generator of random values of `T`.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Pcg64) -> T;
}

impl<T, F: Fn(&mut Pcg64) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Pcg64) -> T {
        self(rng)
    }
}

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized {
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - self.signum());
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as usize).collect()
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 {
            vec![]
        } else {
            vec![0.0, self / 2.0, self.trunc()]
        }
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = vec![];
        if !self.is_empty() {
            out.push(self[..self.len() / 2].to_vec());
            out.push(self[..self.len() - 1].to_vec());
            // shrink one element
            for (i, x) in self.iter().enumerate().take(4) {
                for sx in x.shrink() {
                    let mut v = self.clone();
                    v[i] = sx;
                    out.push(v);
                }
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> =
            self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run `prop` on `cases` random inputs; on failure, shrink (up to 200
/// candidates) and panic with the smallest counterexample found.
pub fn check<T, G, P>(name: &str, gen: G, prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    check_n(name, default_cases(), gen, prop)
}

pub fn check_n<T, G, P>(name: &str, cases: usize, gen: G, prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let seed = std::env::var("A3PO_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xa3b0);
    let mut rng = Pcg64::from_seed(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink.
            let mut best = (input.clone(), msg.clone());
            let mut frontier = input.shrink();
            let mut budget = 200usize;
            while let Some(cand) = frontier.pop() {
                if budget == 0 {
                    break;
                }
                budget -= 1;
                if let Err(m) = prop(&cand) {
                    frontier = cand.shrink();
                    best = (cand, m);
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n  \
                 counterexample: {:?}\n  error: {}",
                best.0, best.1
            );
        }
    }
}

/// Convenience generators.
pub mod gens {
    use super::super::rng::Pcg64;

    pub fn vec_f64(len_max: usize, lo: f64, hi: f64) -> impl Fn(&mut Pcg64) -> Vec<f64> {
        move |rng| {
            let n = 1 + rng.below(len_max.max(1) as u64) as usize;
            (0..n).map(|_| lo + rng.next_f64() * (hi - lo)).collect()
        }
    }

    pub fn u64_below(n: u64) -> impl Fn(&mut Pcg64) -> u64 {
        move |rng| rng.below(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", gens::vec_f64(8, -10.0, 10.0), |v| {
            let a: f64 = v.iter().sum();
            let b: f64 = v.iter().rev().sum();
            if (a - b).abs() < 1e-9 {
                Ok(())
            } else {
                Err(format!("{a} != {b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_counterexample() {
        check("always fails", gens::u64_below(100), |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_finds_small_case() {
        // Property fails for any value >= 10; shrinker should find exactly 10
        // often, but at minimum a value < the original failing one.
        let result = std::panic::catch_unwind(|| {
            check("ge10", gens::u64_below(1000), |x| {
                if *x < 10 {
                    Ok(())
                } else {
                    Err(format!("{x} >= 10"))
                }
            });
        });
        assert!(result.is_err());
    }
}
