//! Small statistics helpers used by metrics, benches, and the evaluators.

/// Online mean/variance (Welford) plus min/max tracking.
#[derive(Debug, Clone)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

/// `Default` must match `new()`: a derived default would start min/max at
/// 0.0 and report a spurious 0.0 extremum from any `Running::default()`.
impl Default for Running {
    fn default() -> Self {
        Running::new()
    }
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Binomial pass@1 statistics: mean accuracy with a standard error, matching
/// the paper's Table 2 "pass@1 ± stderr" format.
pub fn pass_at_1(successes: usize, trials: usize) -> (f64, f64) {
    if trials == 0 {
        return (0.0, 0.0);
    }
    let p = successes as f64 / trials as f64;
    let se = (p * (1.0 - p) / trials as f64).sqrt();
    (p, se)
}

/// Percentile over a copy of the data (p in [0, 100], linear interpolation).
/// NaN-safe: samples sort under IEEE total order (NaNs rank last) instead
/// of panicking mid-report the way `partial_cmp().unwrap()` used to.
pub fn percentile(data: &[f64], p: f64) -> f64 {
    if data.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = data.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Exponential moving average smoother for reporting reward curves.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for x in xs {
            r.push(x);
        }
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn default_matches_new_and_tracks_true_extrema() {
        // Regression: a derived Default yielded min = max = 0.0, so the
        // first pushed value could never raise min above 0 (or lower max).
        let mut r = Running::default();
        assert_eq!(r.min(), f64::INFINITY);
        assert_eq!(r.max(), f64::NEG_INFINITY);
        r.push(3.5);
        assert_eq!(r.min(), 3.5);
        assert_eq!(r.max(), 3.5);
        r.push(7.0);
        assert_eq!(r.min(), 3.5);
        assert_eq!(r.max(), 7.0);
    }

    #[test]
    fn pass_at_1_basics() {
        let (p, se) = pass_at_1(30, 100);
        assert!((p - 0.3).abs() < 1e-12);
        assert!(se > 0.0 && se < 0.06);
        assert_eq!(pass_at_1(0, 0), (0.0, 0.0));
    }

    #[test]
    fn percentile_interp() {
        let d = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&d, 0.0), 1.0);
        assert_eq!(percentile(&d, 100.0), 4.0);
        assert!((percentile(&d, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_tolerates_nan_samples() {
        // Regression: the old partial_cmp().unwrap() comparator panicked the
        // moment a NaN metric reached a percentile report.
        let d = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&d, 0.0), 1.0);
        // Total order ranks NaN last: sorted = [1, 2, 3, NaN].
        assert!((percentile(&d, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&d, 100.0).is_nan());
        assert!(percentile(&[f64::NAN; 3], 50.0).is_nan());
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..30 {
            e.push(10.0);
        }
        assert!((e.value().unwrap() - 10.0).abs() < 1e-3);
    }
}
