//! Tiny declarative CLI argument parser (the offline crate set has no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generated `--help` text. Used by the `a3po` binary, the examples, and the
//! bench harnesses.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument set: declare options, then `parse()`.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    specs: Vec<Spec>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args { program: program.into(), about: about.into(), ..Default::default() }
    }

    /// `--key <value>` option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    /// `--key <value>` option that may be absent.
    pub fn opt_optional(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Boolean `--flag`.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.program, self.about);
        let _ = writeln!(s, "USAGE: {} [OPTIONS] [ARGS...]\n\nOPTIONS:", self.program);
        for spec in &self.specs {
            let mut line = format!("  --{}", spec.name);
            if !spec.is_flag {
                line.push_str(" <v>");
            }
            let pad = 26usize.saturating_sub(line.len());
            line.push_str(&" ".repeat(pad.max(1)));
            line.push_str(&spec.help);
            if let Some(d) = &spec.default {
                let _ = write!(line, " [default: {d}]");
            }
            let _ = writeln!(s, "{line}");
        }
        s
    }

    /// Parse from an explicit token list (testable); exits on `--help`.
    pub fn parse_from<I: IntoIterator<Item = String>>(
        mut self,
        argv: I,
    ) -> Result<Parsed, String> {
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                self.values.insert(spec.name.clone(), d.clone());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    self.values.insert(name, "true".into());
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{name} needs a value"))?,
                    };
                    self.values.insert(name, v);
                }
            } else {
                self.positional.push(tok);
            }
        }
        Ok(Parsed { values: self.values, positional: self.positional })
    }

    /// Parse `std::env::args()`, printing usage and exiting on error/help.
    pub fn parse(self) -> Parsed {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(argv) {
            Ok(p) => p,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

/// Parsed argument values with typed accessors.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> &str {
        self.get(name).unwrap_or_else(|| panic!("missing option --{name}"))
    }

    pub fn string(&self, name: &str) -> String {
        self.str(name).to_string()
    }

    pub fn usize(&self, name: &str) -> usize {
        self.parse_num(name)
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.parse_num(name)
    }

    pub fn i64(&self, name: &str) -> i64 {
        self.parse_num(name)
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.parse_num(name)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.get(name) == Some("true")
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    fn parse_num<T: std::str::FromStr>(&self, name: &str) -> T
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.str(name);
        raw.parse().unwrap_or_else(|e| panic!("--{name}={raw}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = Args::new("t", "")
            .opt("steps", "100", "")
            .opt("preset", "tiny", "")
            .flag("verbose", "")
            .parse_from(argv(&["--steps", "5", "--verbose"]))
            .unwrap();
        assert_eq!(p.usize("steps"), 5);
        assert_eq!(p.str("preset"), "tiny");
        assert!(p.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_positional() {
        let p = Args::new("t", "")
            .opt("k", "a", "")
            .parse_from(argv(&["--k=b", "pos1", "pos2"]))
            .unwrap();
        assert_eq!(p.str("k"), "b");
        assert_eq!(p.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        let r = Args::new("t", "").parse_from(argv(&["--nope"]));
        assert!(r.is_err());
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::new("t", "").opt("k", "a", "").parse_from(argv(&["--k"]));
        assert!(r.is_err());
    }

    #[test]
    fn optional_absent() {
        let p = Args::new("t", "")
            .opt_optional("ckpt", "")
            .parse_from(argv(&[]))
            .unwrap();
        assert_eq!(p.get("ckpt"), None);
    }
}
