//! Deterministic PRNG + sampling substrate.
//!
//! `rand` is not in the offline crate universe, so the repo ships its own:
//! a PCG64 (DXSM) generator with split-style reseeding, plus the categorical
//! / top-p / top-k sampling routines the rollout engine's sampler needs.

/// PCG64-DXSM: 128-bit state LCG with a double-xor-shift-multiply output
/// permutation. Fast, small, and statistically solid for simulation use.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    pub fn new(seed: u64, stream: u64) -> Self {
        // SplitMix the seed into 128-bit state/increment.
        let mut sm = SplitMix64::new(seed ^ 0x9e3779b97f4a7c15);
        let s0 = sm.next() as u128;
        let s1 = sm.next() as u128;
        let mut sm2 = SplitMix64::new(stream.wrapping_add(0xda3e39cb94b95bdb));
        let i0 = sm2.next() as u128;
        let i1 = sm2.next() as u128;
        let mut rng = Pcg64 {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1, // increment must be odd
        };
        rng.next_u64();
        rng
    }

    pub fn from_seed(seed: u64) -> Self {
        Self::new(seed, 0x5851f42d4c957f2d)
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn split(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda942042e4dd58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let l = m as u64;
            if l >= n {
                return (m >> 64) as u64;
            }
            // Rejection zone check.
            let t = n.wrapping_neg() % n;
            if l >= t {
                return (m >> 64) as u64;
            }
        }
    }

    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            v.swap(i, j);
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|w| *w as f64).sum();
        debug_assert!(total > 0.0, "categorical needs positive total mass");
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= *w as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// SplitMix64 — used for seeding only.
#[derive(Debug, Clone)]
pub struct SplitMix64(u64);

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64(seed)
    }

    #[inline]
    pub fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::from_seed(7);
        let mut b = Pcg64::from_seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::from_seed(1);
        let mut b = Pcg64::from_seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg64::from_seed(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Pcg64::from_seed(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::from_seed(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::from_seed(6);
        let w = [1.0f32, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.5, "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::from_seed(8);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
