//! In-house substrates: JSON, RNG, CLI parsing, stats, timing, and a mini
//! property-testing harness. These replace crates (serde/rand/clap/
//! proptest/criterion) that are unavailable in this build's offline crate
//! universe — see DESIGN.md "Environment constraints".

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
