//! L3 hot-path micro-benchmarks (criterion stand-in) — §Perf instrumentation.
//!
//! Covers every function on the coordinator's per-step path: sampling,
//! log-softmax, Eq. 3 interpolation, GRPO advantages, batch assembly,
//! buffer push/pop, tokenizer encode/decode, JSON serialisation, literal
//! packing, the shared threaded kernels, and KV-cache decode sessions.
//!
//! The blocked-GEMM section measures the packed microkernels against a
//! faithful replica of the pre-blocking naive kernel on the acceptance
//! shapes (rows=256, d=256, vocab- and d_ff-sized n) and writes the
//! machine-readable `BENCH_kernels.json` (GFLOP/s per path + speedups).
//! The attention section compares the blocked-scalar lane kernels with
//! the dispatched SIMD twins on full-window and decode-step shapes, and
//! measures the attention share of a tiny train step so the kernel
//! speedup is attributable to end-to-end step time.
//!
//!   cargo bench --bench micro_hotpath
//!   cargo bench --bench micro_hotpath -- --out BENCH_kernels.json

use std::path::PathBuf;

use a3po::bench::{bench, kernel_info_json, write_bench_json};
use a3po::buffer::{Episode, EpisodeBuffer};
use a3po::config::{AlphaSchedule, Method, StalenessPolicy};
use a3po::coordinator::advantage::grpo_group_advantages;
use a3po::coordinator::batch::{assemble, TrainBatch};
use a3po::coordinator::trainer::interp_prox_host;
use a3po::coordinator::Trainer;
use a3po::env::{tokenizer, Problem};
use a3po::runtime::native::{kernels, preset as native_preset};
use a3po::runtime::{HostTensor, PresetConfig, Runtime, WeightStore};
use a3po::sampler::{log_softmax, sample, SamplerConfig};
use a3po::util::cli::Args;
use a3po::util::json::Json;
use a3po::util::rng::Pcg64;
use a3po::util::timer::Stopwatch;

fn geo() -> PresetConfig {
    PresetConfig {
        name: "bench".into(),
        vocab: 64,
        seq_len: 48,
        prompt_len: 16,
        gen_len: 32,
        group_size: 4,
        rollout_batch: 32,
        train_batch: 64,
        n_minibatch: 4,
        param_count: 0,
        lr: 1e-3,
        temperature: 1.0,
    }
}

fn episode(rng: &mut Pcg64, version: u64, t: usize, s: usize) -> Episode {
    Episode {
        tokens: (0..s).map(|_| rng.below(64) as i32).collect(),
        behav_logp: (0..t).map(|_| -rng.next_f32() * 3.0).collect(),
        mask: (0..t).map(|i| if i >= 15 { 1.0 } else { 0.0 }).collect(),
        reward: rng.next_f64(),
        reward_exact: 0.0,
        version,
        group: 0,
        text: "42".into(),
        problem: Problem { prompt: "6*7=".into(), answer: "42".into() },
    }
}

/// Deterministic synthetic RL batch (same shape the coordinator builds),
/// for the attention-share-of-train-step measurement.
fn synthetic_batch(rng: &mut Pcg64, geo: &PresetConfig) -> TrainBatch {
    let (b, s) = (geo.train_batch, geo.seq_len);
    let t = s - 1;
    let tokens = (0..b * s).map(|_| rng.below(geo.vocab as u64) as i32).collect();
    let mask = (0..b * t).map(|i| if i % t >= t - geo.gen_len { 1.0 } else { 0.0 }).collect();
    let behav_logp = (0..b * t).map(|_| -0.1 - 2.0 * rng.next_f32()).collect();
    let adv = (0..b * t).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
    let alpha = (0..b).map(|_| rng.next_f32()).collect();
    TrainBatch {
        tokens,
        mask,
        behav_logp,
        adv,
        alpha,
        staleness: vec![0; b],
        mean_staleness: 0.0,
        mean_alpha: 0.0,
        mean_reward: 0.0,
        mean_reward_exact: 0.0,
    }
}

/// Faithful replica of the kernel this PR replaced: scalar triple loop
/// with the `av == 0.0` skip branch (which blocked autovectorization),
/// rows fanned out as one boxed job per row chunk through the pool — the
/// "before" side of the BENCH_kernels.json comparison.
#[allow(clippy::manual_div_ceil)] // usize::div_ceil needs rustc >= 1.73
fn naive_matmul_old(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threaded: bool,
) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    let do_rows = |cc: &mut [f32], i0: usize| {
        for (ri, crow) in cc.chunks_mut(n).enumerate() {
            let i = i0 + ri;
            for p in 0..k {
                let av = a[i * k + p];
                if av == 0.0 {
                    continue;
                }
                let brow = &b[p * n..(p + 1) * n];
                for j in 0..n {
                    crow[j] += av * brow[j];
                }
            }
        }
    };
    if !threaded || kernels::pool().workers() < 2 {
        do_rows(&mut c, 0);
        return c;
    }
    let workers = kernels::pool().workers();
    let rows_per_job = ((m + workers * 4 - 1) / (workers * 4)).max(1);
    let dr = &do_rows;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    for (ci, chunk) in c.chunks_mut(rows_per_job * n).enumerate() {
        jobs.push(Box::new(move || dr(chunk, ci * rows_per_job)));
    }
    kernels::pool().run(jobs);
    c
}

fn main() -> anyhow::Result<()> {
    let parsed = Args::new(
        "micro_hotpath",
        "coordinator hot-path micro-benchmarks + blocked-GEMM GFLOP/s comparison",
    )
    .opt("out", "BENCH_kernels.json", "machine-readable kernel-bench output path")
    .flag("bench", "(ignored; passed by cargo bench)")
    .parse();

    let mut rng = Pcg64::from_seed(0);
    let g = geo();
    let (s, t) = (g.seq_len, g.seq_len - 1);

    println!("\n== L3 hot-path micro-benchmarks ==\n");

    // Sampler path (called once per generated token per sequence).
    let logits: Vec<f32> = (0..64).map(|_| rng.next_f32() * 8.0 - 4.0).collect();
    let cfg = SamplerConfig::default();
    let mut srng = Pcg64::from_seed(1);
    bench("sampler::sample (V=64, full vocab)", 20_000, || {
        std::hint::black_box(sample(&logits, &cfg, &mut srng));
    });
    bench("sampler::log_softmax (V=64)", 20_000, || {
        std::hint::black_box(log_softmax(&logits, 1.0));
    });

    // Eq. 3 interpolation over a full train batch (the Fig. 1 op).
    let theta: Vec<f32> = (0..g.train_batch * t).map(|_| -rng.next_f32()).collect();
    let behav: Vec<f32> = (0..g.train_batch * t).map(|_| -rng.next_f32()).collect();
    let alpha: Vec<f32> = (0..g.train_batch).map(|_| rng.next_f32()).collect();
    bench("trainer::interp_prox_host (64x47)", 5_000, || {
        std::hint::black_box(interp_prox_host(&theta, &behav, &alpha, t));
    });

    // GRPO advantages.
    let rewards = [0.2f64, 1.0, 0.0, 0.7];
    bench("advantage::grpo_group_advantages (G=4)", 50_000, || {
        std::hint::black_box(grpo_group_advantages(&rewards));
    });

    // Batch assembly from 16 groups of 4.
    let groups: Vec<Vec<Episode>> = (0..16)
        .map(|_| (0..4).map(|_| episode(&mut rng, 3, t, s)).collect())
        .collect();
    bench("batch::assemble (64 episodes)", 2_000, || {
        std::hint::black_box(assemble(&groups, &g, 5, AlphaSchedule::InverseD, 0));
    });

    // Buffer push/pop throughput.
    let buf = EpisodeBuffer::new(StalenessPolicy { max_staleness: 8, max_buffered: 100_000 });
    let mut brng = Pcg64::from_seed(2);
    bench("buffer::push+pop group (G=4)", 5_000, || {
        let grp: Vec<Episode> = (0..4).map(|_| episode(&mut brng, 0, t, s)).collect();
        buf.push_group(grp);
        std::hint::black_box(buf.try_pop_groups(1, 0));
    });

    // Tokenizer.
    bench("tokenizer::encode_prompt_padded", 50_000, || {
        std::hint::black_box(tokenizer::encode_prompt_padded("((417+88)%53*9)%41=", 36));
    });
    bench("tokenizer::decode (32 tokens)", 50_000, || {
        let toks: Vec<i32> = (4..36).collect();
        std::hint::black_box(tokenizer::decode(&toks));
    });

    // JSON metrics serialisation (per-step logging cost).
    let j = Json::obj(vec![
        ("step", Json::Num(12.0)),
        ("reward", Json::Num(0.734)),
        ("train", Json::arr_f64(&[0.1, 2.0, 1.5, 0.5, 10.0, 1.0, 0.9, 0.01])),
    ]);
    bench("json::dump (step record)", 50_000, || {
        std::hint::black_box(j.dump());
    });

    // Host-tensor packing for a train batch (the per-step input build).
    let tokens: Vec<i32> = (0..g.train_batch * s).map(|_| rng.below(64) as i32).collect();
    bench("tensor::HostTensor::i32 pack (64x48)", 5_000, || {
        std::hint::black_box(HostTensor::i32(vec![g.train_batch, s], tokens.clone()));
    });

    // Shared dense kernels: threaded vs single-thread (setup1-shaped op).
    let (m, kd, n) = (64usize, 192usize, 192usize);
    let ma: Vec<f32> = (0..m * kd).map(|_| rng.next_f32() - 0.5).collect();
    let mb: Vec<f32> = (0..kd * n).map(|_| rng.next_f32() - 0.5).collect();
    bench(
        &format!("kernels::matmul {m}x{kd}x{n} ({} threads)", kernels::pool().workers()),
        2_000,
        || {
            std::hint::black_box(kernels::matmul(&ma, &mb, m, kd, n));
        },
    );
    kernels::set_force_serial(true);
    bench(&format!("kernels::matmul {m}x{kd}x{n} (serial)"), 2_000, || {
        std::hint::black_box(kernels::matmul(&ma, &mb, m, kd, n));
    });
    kernels::set_force_serial(false);

    // KV-cache decode session: prompt prefill + a full tiny generation
    // window, the rollout engine's per-batch hot path.
    let rt = Runtime::native("tiny", Some(&["init", "decode"])).unwrap();
    let tiny = rt.manifest.preset.clone();
    let snapshot = rt.init_params(0).unwrap();
    let decoder = rt.decoder().unwrap();
    let prompts: Vec<i32> =
        (0..tiny.rollout_batch * tiny.prompt_len).map(|i| 3 + (i % 60) as i32).collect();
    bench("decode_session: prefill + gen window (tiny)", 50, || {
        let mut session = decoder
            .start(&snapshot, &prompts, tiny.rollout_batch, tiny.prompt_len)
            .unwrap();
        for pos in tiny.prompt_len..tiny.seq_len - 1 {
            let toks: Vec<i32> =
                (0..session.active_rows()).map(|r| 3 + ((r + pos) % 60) as i32).collect();
            session.step(&toks).unwrap();
        }
        std::hint::black_box(session.logits()[0]);
    });

    // Blocked GEMM (scalar tile and the dispatched SIMD tile) vs the
    // pre-blocking naive kernel on the acceptance shapes: rows=256 x d=256
    // against a vocab-sized and a d_ff-sized n.
    println!("\n== Blocked GEMM: naive vs blocked-scalar vs dispatched tile (GFLOP/s) ==\n");
    let info = kernels::kernel_info();
    println!(
        "kernel path: isa={} (simd_available={}), tile {}x{}x{}, {} threads\n",
        info.isa.name(),
        info.simd_available,
        info.mr,
        info.nr,
        info.kc,
        info.threads
    );
    let threads = kernels::pool().workers();
    let mut shape_rows: Vec<Json> = Vec::new();
    let mut min_speedup = f64::INFINITY;
    let mut min_speedup_simd = f64::INFINITY;
    for (m, kd, n) in [(256usize, 256usize, 64usize), (256, 256, 1024)] {
        let flops = 2.0 * (m * kd * n) as f64;
        let gflops = |mean_ns: f64| flops / mean_ns.max(1e-9);
        let iters = if n >= 512 { 8 } else { 40 };
        let a: Vec<f32> = (0..m * kd).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> = (0..kd * n).map(|_| rng.next_f32() - 0.5).collect();

        // Cross-check the baseline replica against the shipped kernel, and
        // pin scalar-vs-dispatched bit-equality, before timing anything.
        let c_old = naive_matmul_old(&a, &b, m, kd, n, false);
        let c_new = kernels::matmul(&a, &b, m, kd, n);
        for (x, y) in c_old.iter().zip(&c_new) {
            assert!((x - y).abs() < 1e-2, "baseline replica diverged: {x} vs {y}");
        }
        kernels::set_kernel_override(Some(kernels::KernelIsa::Scalar));
        let c_scalar = kernels::matmul(&a, &b, m, kd, n);
        kernels::set_kernel_override(None);
        assert_eq!(c_scalar, c_new, "scalar vs dispatched tile diverged (must be bit-identical)");

        let old_thr = bench(&format!("naive matmul {m}x{kd}x{n} ({threads} thr)"), iters, || {
            std::hint::black_box(naive_matmul_old(&a, &b, m, kd, n, true));
        });
        kernels::set_kernel_override(Some(kernels::KernelIsa::Scalar));
        let scl_thr =
            bench(&format!("blocked-scalar matmul {m}x{kd}x{n} ({threads} thr)"), iters, || {
                std::hint::black_box(kernels::matmul(&a, &b, m, kd, n));
            });
        kernels::set_kernel_override(None);
        let lbl = format!("blocked-{} matmul {m}x{kd}x{n} ({threads} thr)", info.isa.name());
        let new_thr = bench(&lbl, iters, || {
            std::hint::black_box(kernels::matmul(&a, &b, m, kd, n));
        });
        kernels::set_force_serial(true);
        let old_ser = bench(&format!("naive matmul {m}x{kd}x{n} (serial)"), iters, || {
            std::hint::black_box(naive_matmul_old(&a, &b, m, kd, n, false));
        });
        kernels::set_kernel_override(Some(kernels::KernelIsa::Scalar));
        let scl_ser = bench(&format!("blocked-scalar matmul {m}x{kd}x{n} (serial)"), iters, || {
            std::hint::black_box(kernels::matmul(&a, &b, m, kd, n));
        });
        kernels::set_kernel_override(None);
        let lbl = format!("blocked-{} matmul {m}x{kd}x{n} (serial)", info.isa.name());
        let new_ser = bench(&lbl, iters, || {
            std::hint::black_box(kernels::matmul(&a, &b, m, kd, n));
        });
        kernels::set_force_serial(false);

        let speedup_thr = gflops(new_thr.mean_ns) / gflops(old_thr.mean_ns);
        let speedup_ser = gflops(new_ser.mean_ns) / gflops(old_ser.mean_ns);
        min_speedup = min_speedup.min(speedup_thr);
        let (simd_thr, simd_ser) = if info.simd_available {
            let st = gflops(new_thr.mean_ns) / gflops(scl_thr.mean_ns);
            let ss = gflops(new_ser.mean_ns) / gflops(scl_ser.mean_ns);
            min_speedup_simd = min_speedup_simd.min(st);
            (Json::Num(st), Json::Num(ss))
        } else {
            (Json::Null, Json::Null)
        };
        println!(
            "  {m}x{kd}x{n} threaded: naive {:.2} | blocked-scalar {:.2} | {} {:.2} GFLOP/s \
             ({speedup_thr:.2}x vs naive); serial: {:.2} | {:.2} | {:.2} ({speedup_ser:.2}x)\n",
            gflops(old_thr.mean_ns),
            gflops(scl_thr.mean_ns),
            info.isa.name(),
            gflops(new_thr.mean_ns),
            gflops(old_ser.mean_ns),
            gflops(scl_ser.mean_ns),
            gflops(new_ser.mean_ns),
        );
        shape_rows.push(Json::obj(vec![
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(kd as f64)),
            ("n", Json::Num(n as f64)),
            ("naive_threaded_gflops", Json::Num(gflops(old_thr.mean_ns))),
            ("naive_serial_gflops", Json::Num(gflops(old_ser.mean_ns))),
            ("blocked_scalar_threaded_gflops", Json::Num(gflops(scl_thr.mean_ns))),
            ("blocked_scalar_serial_gflops", Json::Num(gflops(scl_ser.mean_ns))),
            ("blocked_threaded_gflops", Json::Num(gflops(new_thr.mean_ns))),
            ("blocked_serial_gflops", Json::Num(gflops(new_ser.mean_ns))),
            ("speedup_blocked_vs_naive_threaded", Json::Num(speedup_thr)),
            ("speedup_blocked_vs_naive_serial", Json::Num(speedup_ser)),
            ("speedup_simd_vs_scalar_threaded", simd_thr),
            ("speedup_simd_vs_scalar_serial", simd_ser),
        ]));
    }

    // Fused q/k/v projection: three matmul_set calls vs one
    // matmul_set_multi sharing the A micropanel pack (the model.rs shape).
    println!("== Fused q/k/v projection: separate vs multi-B (GFLOP/s) ==\n");
    let qkv = {
        let (m, kd, n) = (256usize, 256usize, 256usize);
        let flops = 3.0 * 2.0 * (m * kd * n) as f64;
        let gflops = |mean_ns: f64| flops / mean_ns.max(1e-9);
        let a: Vec<f32> = (0..m * kd).map(|_| rng.next_f32() - 0.5).collect();
        let bs: Vec<Vec<f32>> =
            (0..3).map(|_| (0..kd * n).map(|_| rng.next_f32() - 0.5).collect()).collect();
        let mut sep: Vec<Vec<f32>> = (0..3).map(|_| vec![0.0f32; m * n]).collect();
        let mut multi: Vec<Vec<f32>> = (0..3).map(|_| vec![f32::NAN; m * n]).collect();

        // Correctness first: the fused path must match three singles
        // bit-for-bit.
        for (c, b) in sep.iter_mut().zip(bs.iter()) {
            kernels::matmul_set(c, &a, b, m, kd, n);
        }
        {
            let (c0, rest) = multi.split_first_mut().unwrap();
            let (c1, rest) = rest.split_first_mut().unwrap();
            let c2 = &mut rest[0];
            kernels::matmul_set_multi(
                [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                &a,
                [&bs[0], &bs[1], &bs[2]],
                m,
                kd,
                n,
            );
        }
        assert_eq!(sep, multi, "matmul_set_multi diverged from three matmul_set calls");

        let sep_stats = bench(&format!("3x matmul_set {m}x{kd}x{n} (q/k/v)"), 20, || {
            for (c, b) in sep.iter_mut().zip(bs.iter()) {
                kernels::matmul_set(c, &a, b, m, kd, n);
            }
            std::hint::black_box(sep[0][0]);
        });
        let multi_stats = bench(&format!("matmul_set_multi {m}x{kd}x{n} (q/k/v)"), 20, || {
            let (c0, rest) = multi.split_first_mut().unwrap();
            let (c1, rest) = rest.split_first_mut().unwrap();
            let c2 = &mut rest[0];
            kernels::matmul_set_multi(
                [c0.as_mut_slice(), c1.as_mut_slice(), c2.as_mut_slice()],
                &a,
                [&bs[0], &bs[1], &bs[2]],
                m,
                kd,
                n,
            );
            std::hint::black_box(multi[0][0]);
        });
        let speedup = gflops(multi_stats.mean_ns) / gflops(sep_stats.mean_ns);
        println!(
            "  q/k/v {m}x{kd}x{n}: multi-B {:.2} GFLOP/s vs separate {:.2} GFLOP/s \
             ({speedup:.2}x)\n",
            gflops(multi_stats.mean_ns),
            gflops(sep_stats.mean_ns),
        );
        Json::obj(vec![
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(kd as f64)),
            ("n", Json::Num(n as f64)),
            ("separate_gflops", Json::Num(gflops(sep_stats.mean_ns))),
            ("multi_gflops", Json::Num(gflops(multi_stats.mean_ns))),
            ("speedup_multi_vs_separate", Json::Num(speedup)),
        ])
    };

    // Attention kernels: blocked-scalar lanes vs the dispatched SIMD
    // twins on full-window (train-shaped) and decode-step shapes. FLOP
    // counts follow the causal window: forward does ~4*hd mul+adds per
    // (i, j<=i) pair per head, backward ~8*hd; a decode step is one
    // query row against pos+1 cached keys per head.
    println!("== Attention: blocked-scalar vs dispatched lanes (GFLOP/s) ==\n");
    let isa = info.isa.name();
    let gf = |flops: f64, ns: f64| flops / ns.max(1e-9);
    let jnum = |x: f64| if info.simd_available { Json::Num(x) } else { Json::Null };
    let mut attn_rows: Vec<Json> = Vec::new();
    let mut min_attn_simd = f64::INFINITY;
    for (b, s, h, hd) in [(4usize, 128usize, 4usize, 64usize), (2, 192, 2, 128)] {
        let d = h * hd;
        let pairs = (s * (s + 1) / 2) as f64;
        let fwd_flops = (b * h * 4 * hd) as f64 * pairs;
        let bwd_flops = (b * h * 8 * hd) as f64 * pairs;
        let q: Vec<f32> = (0..b * s * d).map(|_| rng.next_f32() - 0.5).collect();
        let k: Vec<f32> = (0..b * s * d).map(|_| rng.next_f32() - 0.5).collect();
        let v: Vec<f32> = (0..b * s * d).map(|_| rng.next_f32() - 0.5).collect();
        let dctx: Vec<f32> = (0..b * s * d).map(|_| rng.next_f32() - 0.5).collect();
        let mut probs = vec![0.0f32; b * h * s * s];
        let mut ctx = vec![0.0f32; b * s * d];
        let mut dq = vec![0.0f32; b * s * d];
        let mut dk = vec![0.0f32; b * s * d];
        let mut dv = vec![0.0f32; b * s * d];

        // Pin scalar-vs-dispatched bit-equality before timing anything.
        kernels::set_kernel_override(Some(kernels::KernelIsa::Scalar));
        kernels::attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
        let (probs_ref, ctx_ref) = (probs.clone(), ctx.clone());
        kernels::set_kernel_override(None);
        kernels::attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
        assert_eq!(probs_ref, probs, "attention fwd scalar vs dispatched diverged");
        assert_eq!(ctx_ref, ctx, "attention fwd scalar vs dispatched diverged");

        let iters = 30;
        kernels::set_kernel_override(Some(kernels::KernelIsa::Scalar));
        let fwd_scl =
            bench(&format!("attn fwd {b}x{s} h{h} hd{hd} scalar ({threads} thr)"), iters, || {
                kernels::attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
                std::hint::black_box(ctx[0]);
            });
        let bwd_scl =
            bench(&format!("attn bwd {b}x{s} h{h} hd{hd} scalar ({threads} thr)"), iters, || {
                dq.fill(0.0);
                dk.fill(0.0);
                dv.fill(0.0);
                kernels::attention_backward(
                    b, s, h, hd, &probs, &q, &k, &v, &dctx, &mut dq, &mut dk, &mut dv,
                );
                std::hint::black_box(dq[0]);
            });
        kernels::set_kernel_override(None);
        let fwd_new =
            bench(&format!("attn fwd {b}x{s} h{h} hd{hd} {isa} ({threads} thr)"), iters, || {
                kernels::attention_forward(b, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
                std::hint::black_box(ctx[0]);
            });
        let bwd_new =
            bench(&format!("attn bwd {b}x{s} h{h} hd{hd} {isa} ({threads} thr)"), iters, || {
                dq.fill(0.0);
                dk.fill(0.0);
                dv.fill(0.0);
                kernels::attention_backward(
                    b, s, h, hd, &probs, &q, &k, &v, &dctx, &mut dq, &mut dk, &mut dv,
                );
                std::hint::black_box(dq[0]);
            });

        let fwd_speedup = fwd_scl.mean_ns / fwd_new.mean_ns.max(1e-9);
        let bwd_speedup = bwd_scl.mean_ns / bwd_new.mean_ns.max(1e-9);
        if info.simd_available {
            min_attn_simd = min_attn_simd.min(fwd_speedup).min(bwd_speedup);
        }
        println!(
            "  attn {b}x{s} h{h} hd{hd}: fwd scalar {:.2} | {isa} {:.2} GFLOP/s \
             ({fwd_speedup:.2}x); bwd scalar {:.2} | {isa} {:.2} ({bwd_speedup:.2}x)\n",
            gf(fwd_flops, fwd_scl.mean_ns),
            gf(fwd_flops, fwd_new.mean_ns),
            gf(bwd_flops, bwd_scl.mean_ns),
            gf(bwd_flops, bwd_new.mean_ns),
        );
        attn_rows.push(Json::obj(vec![
            ("kind", Json::Str("full_window".into())),
            ("b", Json::Num(b as f64)),
            ("s", Json::Num(s as f64)),
            ("h", Json::Num(h as f64)),
            ("hd", Json::Num(hd as f64)),
            ("forward_scalar_gflops", Json::Num(gf(fwd_flops, fwd_scl.mean_ns))),
            ("forward_dispatched_gflops", Json::Num(gf(fwd_flops, fwd_new.mean_ns))),
            ("backward_scalar_gflops", Json::Num(gf(bwd_flops, bwd_scl.mean_ns))),
            ("backward_dispatched_gflops", Json::Num(gf(bwd_flops, bwd_new.mean_ns))),
            ("speedup_forward_simd_vs_scalar", jnum(fwd_speedup)),
            ("speedup_backward_simd_vs_scalar", jnum(bwd_speedup)),
        ]));
    }

    // Decode-step shape: a late position against a full KV window, the
    // rollout engine's steady-state per-token cost.
    {
        let (rows, cap, h, hd) = (64usize, 192usize, 2usize, 128usize);
        let pos = cap - 1;
        let d = h * hd;
        let flops = (rows * h * (pos + 1) * 4 * hd) as f64;
        let q: Vec<f32> = (0..rows * d).map(|_| rng.next_f32() - 0.5).collect();
        let kc: Vec<f32> = (0..rows * cap * d).map(|_| rng.next_f32() - 0.5).collect();
        let vc: Vec<f32> = (0..rows * cap * d).map(|_| rng.next_f32() - 0.5).collect();
        let mut ctx = vec![0.0f32; rows * d];

        kernels::set_kernel_override(Some(kernels::KernelIsa::Scalar));
        kernels::attention_decode_step(rows, cap, pos, h, hd, &q, &kc, &vc, &mut ctx);
        let ctx_ref = ctx.clone();
        kernels::set_kernel_override(None);
        kernels::attention_decode_step(rows, cap, pos, h, hd, &q, &kc, &vc, &mut ctx);
        assert_eq!(ctx_ref, ctx, "attention decode scalar vs dispatched diverged");

        let iters = 200;
        kernels::set_kernel_override(Some(kernels::KernelIsa::Scalar));
        let scl = bench(
            &format!("attn decode r{rows} cap{cap} h{h} hd{hd} scalar ({threads} thr)"),
            iters,
            || {
                kernels::attention_decode_step(rows, cap, pos, h, hd, &q, &kc, &vc, &mut ctx);
                std::hint::black_box(ctx[0]);
            },
        );
        kernels::set_kernel_override(None);
        let new = bench(
            &format!("attn decode r{rows} cap{cap} h{h} hd{hd} {isa} ({threads} thr)"),
            iters,
            || {
                kernels::attention_decode_step(rows, cap, pos, h, hd, &q, &kc, &vc, &mut ctx);
                std::hint::black_box(ctx[0]);
            },
        );
        let speedup = scl.mean_ns / new.mean_ns.max(1e-9);
        if info.simd_available {
            min_attn_simd = min_attn_simd.min(speedup);
        }
        println!(
            "  attn decode r{rows} cap{cap} h{h} hd{hd}: scalar {:.2} | {isa} {:.2} GFLOP/s \
             ({speedup:.2}x)\n",
            gf(flops, scl.mean_ns),
            gf(flops, new.mean_ns),
        );
        attn_rows.push(Json::obj(vec![
            ("kind", Json::Str("decode_step".into())),
            ("rows", Json::Num(rows as f64)),
            ("cap", Json::Num(cap as f64)),
            ("pos", Json::Num(pos as f64)),
            ("h", Json::Num(h as f64)),
            ("hd", Json::Num(hd as f64)),
            ("scalar_gflops", Json::Num(gf(flops, scl.mean_ns))),
            ("dispatched_gflops", Json::Num(gf(flops, new.mean_ns))),
            ("speedup_simd_vs_scalar", jnum(speedup)),
        ]));
    }

    // Attention share of a tiny train step: time full session steps, then
    // time just the attention forward+backward those steps contain
    // (n_layers * n_minibatch causal windows at minibatch geometry), so
    // the kernel speedup above is attributable to end-to-end step time.
    println!("== Attention share of a tiny train step ==\n");
    let attn_share = {
        let rt = Runtime::native("tiny", Some(&["init", "train_loglinear"]))?;
        let tgeo = rt.manifest.preset.clone();
        let dims = native_preset("tiny").expect("tiny preset exists").dims;
        let init = rt.init_params(0)?;
        let store = WeightStore::new(init.clone());
        let mut trainer = Trainer::new(&rt, Method::Loglinear, init, store)?;
        let mut brng = Pcg64::from_seed(0xA77);
        let reps = 20usize;
        let mut batches: Vec<TrainBatch> =
            (0..2 + reps).map(|_| synthetic_batch(&mut brng, &tgeo)).collect();
        let timed = batches.split_off(2);
        for batch in batches {
            trainer.step(batch)?;
        }
        let sw = Stopwatch::start();
        let mut sink = 0.0;
        for batch in timed {
            sink += trainer.step(batch)?.0.loss;
        }
        let step_secs = sw.secs() / reps as f64;
        std::hint::black_box(sink);

        let (h, hd) = (dims.n_heads, dims.head_dim());
        let d = h * hd;
        let mb_rows = tgeo.train_batch / tgeo.n_minibatch;
        let s = tgeo.seq_len;
        let q: Vec<f32> = (0..mb_rows * s * d).map(|_| rng.next_f32() - 0.5).collect();
        let k: Vec<f32> = (0..mb_rows * s * d).map(|_| rng.next_f32() - 0.5).collect();
        let v: Vec<f32> = (0..mb_rows * s * d).map(|_| rng.next_f32() - 0.5).collect();
        let dctx: Vec<f32> = (0..mb_rows * s * d).map(|_| rng.next_f32() - 0.5).collect();
        let mut probs = vec![0.0f32; mb_rows * h * s * s];
        let mut ctx = vec![0.0f32; mb_rows * s * d];
        let mut dq = vec![0.0f32; mb_rows * s * d];
        let mut dk = vec![0.0f32; mb_rows * s * d];
        let mut dv = vec![0.0f32; mb_rows * s * d];
        let windows = dims.n_layers * tgeo.n_minibatch;
        let sw = Stopwatch::start();
        for _ in 0..reps {
            for _ in 0..windows {
                kernels::attention_forward(mb_rows, s, h, hd, &q, &k, &v, &mut probs, &mut ctx);
                dq.fill(0.0);
                dk.fill(0.0);
                dv.fill(0.0);
                kernels::attention_backward(
                    mb_rows, s, h, hd, &probs, &q, &k, &v, &dctx, &mut dq, &mut dk, &mut dv,
                );
            }
        }
        let attn_secs = sw.secs() / reps as f64;
        std::hint::black_box(dq[0]);
        let share = attn_secs / step_secs.max(1e-12);
        println!(
            "  step {:.3} ms, attention fwd+bwd {:.3} ms -> {:.1}% of step\n",
            step_secs * 1e3,
            attn_secs * 1e3,
            share * 100.0
        );
        share
    };

    println!("min blocked-vs-naive speedup: {min_speedup:.2}x (target >= 3x)");
    let min_simd_json = if info.simd_available {
        println!("min simd-vs-scalar speedup: {min_speedup_simd:.2}x (target >= 1.5x)");
        Json::Num(min_speedup_simd)
    } else {
        println!("simd unavailable on this host: simd-vs-scalar comparison skipped");
        Json::Null
    };
    let min_attn_json = if info.simd_available {
        println!("min attention simd-vs-scalar speedup: {min_attn_simd:.2}x (target >= 1.5x)");
        Json::Num(min_attn_simd)
    } else {
        Json::Null
    };
    write_bench_json(
        &PathBuf::from(parsed.str("out")),
        &Json::obj(vec![
            ("kernel", kernel_info_json()),
            ("kernel_threads", Json::Num(threads as f64)),
            ("shapes", Json::Arr(shape_rows)),
            ("qkv", qkv),
            ("attention_shapes", Json::Arr(attn_rows)),
            ("min_speedup_vs_naive", Json::Num(min_speedup)),
            ("target_speedup_vs_naive", Json::Num(3.0)),
            ("min_speedup_simd_vs_scalar", min_simd_json),
            ("target_speedup_simd_vs_scalar", Json::Num(1.5)),
            ("min_attention_speedup_simd_vs_scalar", min_attn_json),
            ("target_attention_speedup_simd_vs_scalar", Json::Num(1.5)),
            ("attention_share_of_train_step", Json::Num(attn_share)),
        ]),
    )?;
    Ok(())
}
