//! Train-step throughput: stateful train session vs the positional
//! executable path, with threaded vs single-thread kernels.
//!
//! The session path keeps parameters, Adam moments, and the activation
//! workspace in-place inside the backend; a step moves only the batch in
//! and metrics out, plus one copy-on-publish parameter snapshot. The
//! positional path round-trips the full optimiser state through the
//! executable every step. Both run identical math (see
//! `rust/tests/train_parity.rs`); this bench measures what the state
//! transfer and allocation churn cost.
//!
//! Emits a machine-readable `BENCH_train.json` with steps/sec plus mean
//! per-step heap allocations (count and bytes), counted by a wrapping
//! global allocator. A `session_scalar` row pins `A3PO_KERNEL=scalar`
//! so the SIMD contribution (GEMM + attention lanes) is visible. Acceptance: session train_loglinear steps/sec >=
//! 1.3x the positional path on the tiny preset.
//!
//!   cargo bench --bench train_step -- --preset tiny
//!   cargo bench --bench train_step -- --preset tiny --out BENCH_train.json

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use a3po::bench::{kernel_info_json, write_bench_json};
use a3po::config::Method;
use a3po::coordinator::batch::TrainBatch;
use a3po::coordinator::Trainer;
use a3po::runtime::native::train::train_step_gemm_flops;
use a3po::runtime::native::{kernels, preset as native_preset};
use a3po::runtime::{PresetConfig, Runtime, WeightStore};
use a3po::util::cli::Args;
use a3po::util::json::Json;
use a3po::util::rng::Pcg64;
use a3po::util::timer::Stopwatch;

/// [`System`] allocator wrapper that counts allocations so the bench can
/// report per-step heap churn (all threads, which is what we want: the
/// kernel pool's allocations count too).
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size.saturating_sub(layout.size()) as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

const EXECS: &[&str] = &["init", "train_loglinear"];

/// Deterministic synthetic RL batch (same shape the coordinator builds).
fn synthetic_batch(rng: &mut Pcg64, geo: &PresetConfig) -> TrainBatch {
    let (b, s) = (geo.train_batch, geo.seq_len);
    let t = s - 1;
    let tokens = (0..b * s).map(|_| rng.below(geo.vocab as u64) as i32).collect();
    let mask = (0..b * t).map(|i| if i % t >= t - geo.gen_len { 1.0 } else { 0.0 }).collect();
    let behav_logp = (0..b * t).map(|_| -0.1 - 2.0 * rng.next_f32()).collect();
    let adv = (0..b * t).map(|_| 2.0 * rng.next_f32() - 1.0).collect();
    let alpha = (0..b).map(|_| rng.next_f32()).collect();
    TrainBatch {
        tokens,
        mask,
        behav_logp,
        adv,
        alpha,
        staleness: vec![0; b],
        mean_staleness: 0.0,
        mean_alpha: 0.0,
        mean_reward: 0.0,
        mean_reward_exact: 0.0,
    }
}

struct Measurement {
    steps: u64,
    secs: f64,
    allocs_per_step: f64,
    alloc_bytes_per_step: f64,
}

fn find<'a>(measured: &'a [(&str, Measurement)], name: &str) -> &'a Measurement {
    &measured.iter().find(|(l, _)| *l == name).expect("unmeasured configuration").1
}

fn steps_per_sec(m: &Measurement) -> f64 {
    m.steps as f64 / m.secs.max(1e-12)
}

/// Run `warmup + reps` train_loglinear steps down one path; measure the
/// timed portion. Batches are pre-built so batch synthesis never lands in
/// the timing or allocation window (steps take them by move).
fn drive(
    rt: &Runtime,
    geo: &PresetConfig,
    use_sessions: bool,
    reps: usize,
) -> anyhow::Result<Measurement> {
    let init = rt.init_params(0)?;
    let store = WeightStore::new(init.clone());
    let mut trainer = if use_sessions {
        Trainer::new(rt, Method::Loglinear, init, store)?
    } else {
        Trainer::new_without_sessions(rt, Method::Loglinear, init, store)?
    };

    let warmup = 2;
    let mut rng = Pcg64::from_seed(0xBE);
    let mut batches: Vec<TrainBatch> =
        (0..warmup + reps).map(|_| synthetic_batch(&mut rng, geo)).collect();
    let timed = batches.split_off(warmup);
    for batch in batches {
        trainer.step(batch)?;
    }

    let calls0 = ALLOC_CALLS.load(Ordering::Relaxed);
    let bytes0 = ALLOC_BYTES.load(Ordering::Relaxed);
    let sw = Stopwatch::start();
    let mut sink = 0.0;
    for batch in timed {
        let (metrics, _) = trainer.step(batch)?;
        sink += metrics.loss;
    }
    let secs = sw.secs();
    let calls = ALLOC_CALLS.load(Ordering::Relaxed) - calls0;
    let bytes = ALLOC_BYTES.load(Ordering::Relaxed) - bytes0;
    std::hint::black_box(sink);

    Ok(Measurement {
        steps: reps as u64,
        secs,
        allocs_per_step: calls as f64 / reps as f64,
        alloc_bytes_per_step: bytes as f64 / reps as f64,
    })
}

fn main() -> anyhow::Result<()> {
    let parsed = Args::new(
        "train_step",
        "steps/sec + per-step allocations: train sessions vs positional executables",
    )
    .opt("preset", "tiny", "native preset geometry")
    .opt("reps", "0", "measured steps per configuration (0 = auto per preset)")
    .opt("out", "BENCH_train.json", "machine-readable output path")
    .flag("bench", "(ignored; passed by cargo bench)")
    .parse();

    std::env::set_var("A3PO_QUIET", "1");
    let preset = parsed.string("preset");
    let rt = Runtime::native(&preset, Some(EXECS))?;
    let geo = rt.manifest.preset.clone();
    let reps = match parsed.usize("reps") {
        0 if preset == "tiny" => 20,
        0 => 3,
        r => r,
    };
    let threads = kernels::pool().workers();
    // Dense-GEMM work per step (see `train_step_gemm_flops`): steps/sec
    // times this gives the GFLOP/s each path sustains in the matmuls.
    let step_gflop =
        native_preset(&preset).map(|p| train_step_gemm_flops(&p) as f64 / 1e9).unwrap_or(0.0);

    println!("\n== Train step throughput: {} (train_loglinear) ==", preset);
    println!(
        "batch={} seq={} minibatches/step={} params={} kernel threads={} reps={}\n",
        geo.train_batch, geo.seq_len, geo.n_minibatch, geo.param_count, threads, reps
    );

    // (label, session path?, force single-thread kernels?, ISA pin)
    let plan: [(&str, bool, bool, Option<kernels::KernelIsa>); 5] = [
        ("legacy_serial", false, true, None), // the seed train path
        ("legacy", false, false, None),
        ("session_serial", true, true, None),
        ("session_scalar", true, false, Some(kernels::KernelIsa::Scalar)),
        ("session", true, false, None),
    ];
    let mut measured: Vec<(&str, Measurement)> = Vec::new();
    for (label, use_sessions, serial, isa) in plan {
        kernels::set_force_serial(serial);
        kernels::set_kernel_override(isa);
        let res = drive(&rt, &geo, use_sessions, reps);
        kernels::set_force_serial(false);
        kernels::set_kernel_override(None);
        let m = res?;
        let sps = m.steps as f64 / m.secs.max(1e-12);
        println!(
            "{label:<16} {:>4} steps in {:>8.3}s = {sps:>8.2} steps/s = {:>7.2} GFLOP/s  \
             ({:>9.0} allocs/step, {:>12.0} bytes/step)",
            m.steps,
            m.secs,
            sps * step_gflop,
            m.allocs_per_step,
            m.alloc_bytes_per_step
        );
        measured.push((label, m));
    }

    let session = find(&measured, "session");
    let legacy = find(&measured, "legacy");
    let session_serial = find(&measured, "session_serial");
    let session_scalar = find(&measured, "session_scalar");
    let speedup_vs_legacy = steps_per_sec(session) / steps_per_sec(legacy);
    let speedup_threads = steps_per_sec(session) / steps_per_sec(session_serial);
    let speedup_simd = steps_per_sec(session) / steps_per_sec(session_scalar);
    let alloc_ratio = session.allocs_per_step / legacy.allocs_per_step.max(1.0);
    println!("\nsession vs legacy steps/sec       : {speedup_vs_legacy:>6.2}x  (target >= 1.3x)");
    println!("threaded vs serial session kernels: {speedup_threads:>6.2}x");
    println!("session SIMD vs pinned-scalar     : {speedup_simd:>6.2}x");
    println!("session allocs per step vs legacy : {alloc_ratio:>6.3}x");

    let mut pairs: Vec<(&str, Json)> = vec![
        ("preset", Json::Str(preset.clone())),
        ("method", Json::Str("loglinear".to_string())),
        ("train_batch", Json::Num(geo.train_batch as f64)),
        ("seq_len", Json::Num(geo.seq_len as f64)),
        ("n_minibatch", Json::Num(geo.n_minibatch as f64)),
        ("param_count", Json::Num(geo.param_count as f64)),
        ("kernel", kernel_info_json()),
        ("kernel_threads", Json::Num(threads as f64)),
        ("reps", Json::Num(reps as f64)),
        ("dense_gflop_per_step", Json::Num(step_gflop)),
        ("speedup_session_vs_legacy", Json::Num(speedup_vs_legacy)),
        ("speedup_threaded_vs_serial_session", Json::Num(speedup_threads)),
        ("speedup_session_simd_vs_scalar", Json::Num(speedup_simd)),
        ("alloc_ratio_session_vs_legacy", Json::Num(alloc_ratio)),
    ];
    let detail: Vec<(&str, Json)> = measured
        .iter()
        .map(|(label, m)| {
            (
                *label,
                Json::obj(vec![
                    ("steps", Json::Num(m.steps as f64)),
                    ("secs", Json::Num(m.secs)),
                    ("steps_per_sec", Json::Num(m.steps as f64 / m.secs.max(1e-12))),
                    (
                        "dense_gflops_per_sec",
                        Json::Num(step_gflop * m.steps as f64 / m.secs.max(1e-12)),
                    ),
                    ("allocs_per_step", Json::Num(m.allocs_per_step)),
                    ("alloc_bytes_per_step", Json::Num(m.alloc_bytes_per_step)),
                ]),
            )
        })
        .collect();
    pairs.push(("paths", Json::obj(detail)));
    write_bench_json(&PathBuf::from(parsed.str("out")), &Json::obj(pairs))?;
    Ok(())
}
