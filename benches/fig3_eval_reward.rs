//! Figure 3: evaluation reward on held-out test prompts over training steps.
//!
//! Paper shape: Setup 1 — all three methods converge to similar eval
//! rewards; Setup 2 — the asynchronous decoupled methods substantially
//! outperform sync.
//!
//!   cargo bench --bench fig3_eval_reward -- --preset setup1 --steps 80

use a3po::bench::{comparison_runs, BenchConfig};

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env_args(
        "fig3_eval_reward",
        "Fig. 3 — held-out eval reward vs training step, 3 methods",
    );
    let runs = comparison_runs(&cfg)?;

    println!("\n== Fig. 3: held-out eval reward over training ({}) ==", cfg.preset);
    println!("series (step, eval_exact_reward):");
    for r in &runs {
        let series: Vec<String> =
            r.eval_curve.iter().map(|(s, _, rew)| format!("({s}, {rew:.3})")).collect();
        println!("  {:<12} {}", r.method.label(), series.join(" "));
    }

    println!("\n{:<12} {:>12} {:>12}", "method", "final eval", "best eval");
    for r in &runs {
        let best =
            r.eval_curve.iter().map(|(_, _, x)| *x).fold(f64::NEG_INFINITY, f64::max);
        println!("{:<12} {:>12.3} {:>12.3}", r.method.label(), r.final_eval, best);
    }
    let gap = |a: &str, b: &str| {
        let get = |m: &str| {
            runs.iter().find(|r| r.method.label() == m).map(|r| r.final_eval).unwrap_or(0.0)
        };
        get(a) - get(b)
    };
    println!(
        "\nasync-vs-sync gap: loglinear-sync = {:+.3}, recompute-sync = {:+.3}",
        gap("loglinear", "sync"),
        gap("recompute", "sync")
    );
    Ok(())
}
