//! Figure 6: number of clipped tokens per training step, all three methods.
//!
//! Paper shape: recompute and sync clip significantly more tokens than
//! loglinear — A-3PO's contractive ratios naturally stay inside the trust
//! region, wasting fewer tokens.
//!
//!   cargo bench --bench fig6_clipped_tokens -- --preset setup1

use a3po::bench::{comparison_runs, downsample, BenchConfig};

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env_args(
        "fig6_clipped_tokens",
        "Fig. 6 — clipped tokens per training step, 3 methods",
    );
    let runs = comparison_runs(&cfg)?;

    println!("\n== Fig. 6: clipped tokens per training step ({}) ==", cfg.preset);
    println!("series (step, clipped tokens):");
    for r in &runs {
        let pts = downsample(&r.clip_curve, 12);
        let series: Vec<String> =
            pts.iter().map(|(s, c)| format!("({s}, {c:.0})")).collect();
        println!("  {:<12} {}", r.method.label(), series.join(" "));
    }

    println!("\n{:<12} {:>14} {:>14}", "method", "total clipped", "mean / step");
    let mut totals = vec![];
    for r in &runs {
        let total: f64 = r.clip_curve.iter().map(|x| x.1).sum();
        let mean = total / r.clip_curve.len().max(1) as f64;
        totals.push((r.method.label(), total));
        println!("{:<12} {:>14.0} {:>14.2}", r.method.label(), total, mean);
    }
    let get = |m: &str| totals.iter().find(|(l, _)| *l == m).map(|(_, t)| *t).unwrap_or(0.0);
    println!(
        "\nloglinear clips {:.0} vs recompute {:.0} and sync {:.0}  \
         (paper: loglinear clips least)",
        get("loglinear"),
        get("recompute"),
        get("sync")
    );
    Ok(())
}
