//! Figure 2: training progress — average task reward vs wall-clock time for
//! sync / recompute / loglinear at equal training epochs.
//!
//! Paper shape: loglinear reaches the shared final reward fastest;
//! recompute second (it pays a forward pass per step); sync slowest (no
//! rollout/training overlap).
//!
//!   cargo bench --bench fig2_training_progress -- --preset setup1 --steps 80

use a3po::bench::{comparison_runs, downsample, BenchConfig};

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env_args(
        "fig2_training_progress",
        "Fig. 2 — task reward vs wall-clock time, equal epochs, 3 methods",
    );
    let runs = comparison_runs(&cfg)?;

    println!("\n== Fig. 2: training reward vs wall-clock ({} / {} steps) ==", cfg.preset, cfg.steps);
    println!("{:<12} {:>10} {:>10} {:>10}", "method", "t_total(s)", "final rew", "rew@half-t");
    for r in &runs {
        let half_t = r.total_secs / 2.0;
        let rew_half = r
            .reward_curve
            .iter()
            .take_while(|(_, w, _, _)| *w <= half_t)
            .last()
            .map(|(_, _, rew, _)| *rew)
            .unwrap_or(0.0);
        let final_rew = r.reward_curve.last().map(|x| x.2).unwrap_or(0.0);
        println!(
            "{:<12} {:>10.1} {:>10.3} {:>10.3}",
            r.method.label(),
            r.total_secs,
            final_rew,
            rew_half
        );
    }

    println!("\nseries (wallclock_s, shaped_reward):");
    for r in &runs {
        let pts = downsample(&r.reward_curve, 12);
        let series: Vec<String> =
            pts.iter().map(|(_, w, rew, _)| format!("({w:.1}, {rew:.3})")).collect();
        println!("  {:<12} {}", r.method.label(), series.join(" "));
    }

    // The paper's headline: same epochs, loglinear fastest wall-clock.
    let t = |m: &str| {
        runs.iter().find(|r| r.method.label() == m).map(|r| r.total_secs).unwrap_or(0.0)
    };
    println!(
        "\nwall-clock: sync {:.1}s, recompute {:.1}s, loglinear {:.1}s  \
         (paper: loglinear < recompute < sync)",
        t("sync"),
        t("recompute"),
        t("loglinear")
    );
    Ok(())
}
