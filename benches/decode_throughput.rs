//! Decode throughput: KV-cache session decode vs the seed full-forward
//! path, with threaded vs single-thread kernels — the generation-side
//! speedup that makes the paper's prox-phase saving visible at all.
//!
//! Drives a full prompt-prefill + generation window per pass with a fixed
//! non-EOS token stream (worst case: no row finishes early), then emits a
//! machine-readable `BENCH_decode.json` so the perf trajectory is tracked
//! from this PR onward. A `session_scalar` row pins `A3PO_KERNEL=scalar`
//! so the SIMD contribution (GEMM + attention lanes) is visible in the
//! same run. Acceptance: session decode >= 3x tokens/sec over the
//! full-forward path on setup1 geometry.
//!
//!   cargo bench --bench decode_throughput -- --preset setup1
//!   cargo bench --bench decode_throughput -- --preset tiny --out BENCH_decode.json

use std::path::PathBuf;
use std::sync::Arc;

use a3po::bench::{kernel_info_json, write_bench_json};
use a3po::runtime::native::kernels;
use a3po::runtime::{Decoder, ParamSnapshot, PresetConfig, Runtime};
use a3po::util::cli::Args;
use a3po::util::json::Json;
use a3po::util::timer::Stopwatch;

/// Deterministic non-EOS token (ids 0..2 are PAD/BOS/EOS specials).
fn safe_token(geo: &PresetConfig, row: usize, pos: usize) -> i32 {
    (3 + (row * 7 + pos * 11) % (geo.vocab - 3)) as i32
}

/// One measured generation pass set; returns (tokens generated, seconds).
fn drive(
    decoder: &Decoder,
    snapshot: &Arc<ParamSnapshot>,
    geo: &PresetConfig,
    full_forward: bool,
    reps: usize,
) -> anyhow::Result<(u64, f64)> {
    let (br, s, pl) = (geo.rollout_batch, geo.seq_len, geo.prompt_len);
    let mut prompts = vec![0i32; br * pl];
    for r in 0..br {
        for i in 0..pl {
            prompts[r * pl + i] = safe_token(geo, r, i);
        }
    }
    let mut generated = 0u64;
    let mut sink = 0.0f32;
    let sw = Stopwatch::start();
    for _ in 0..reps {
        let mut session = if full_forward {
            decoder.start_full_forward(snapshot, &prompts, br, pl)?
        } else {
            decoder.start(snapshot, &prompts, br, pl)?
        };
        for pos in pl..s {
            sink += session.logits()[0];
            generated += br as u64;
            if pos + 1 == s {
                break;
            }
            let toks: Vec<i32> = (0..br).map(|r| safe_token(geo, r, pos)).collect();
            session.step(&toks)?;
        }
    }
    let secs = sw.secs();
    std::hint::black_box(sink);
    Ok((generated, secs))
}

fn main() -> anyhow::Result<()> {
    let parsed = Args::new(
        "decode_throughput",
        "tokens/sec: session (KV-cache) decode vs full-forward, threaded vs serial kernels",
    )
    .opt("preset", "setup1", "native preset geometry")
    .opt("reps", "0", "generation passes per measurement (0 = auto per preset)")
    .opt("out", "BENCH_decode.json", "machine-readable output path")
    .flag("bench", "(ignored; passed by cargo bench)")
    .parse();

    std::env::set_var("A3PO_QUIET", "1");
    let preset = parsed.string("preset");
    let rt = Runtime::native(&preset, Some(&["init", "decode"]))?;
    let geo = rt.manifest.preset.clone();
    let snapshot = rt.init_params(0)?;
    let decoder = rt.decoder()?;
    let reps = match parsed.usize("reps") {
        0 if preset == "tiny" => 20,
        0 => 3,
        r => r,
    };
    let threads = kernels::pool().workers();

    println!("\n== Decode throughput: {} ==", preset);
    println!(
        "rows={} prompt={} gen={} params={} kernel threads={} reps={}\n",
        geo.rollout_batch,
        geo.prompt_len,
        geo.seq_len - geo.prompt_len,
        geo.param_count,
        threads,
        reps
    );

    // (label, full_forward path?, force single-thread kernels?, ISA pin)
    let plan: [(&str, bool, bool, Option<kernels::KernelIsa>); 5] = [
        ("full_forward_serial", true, true, None), // the seed decode path
        ("full_forward", true, false, None),
        ("session_serial", false, true, None),
        ("session_scalar", false, false, Some(kernels::KernelIsa::Scalar)),
        ("session", false, false, None),
    ];
    let mut measured: Vec<(&str, u64, f64, f64)> = Vec::new();
    for (label, full_forward, serial, isa) in plan {
        kernels::set_force_serial(serial);
        kernels::set_kernel_override(isa);
        let res = drive(&decoder, &snapshot, &geo, full_forward, reps);
        kernels::set_force_serial(false);
        kernels::set_kernel_override(None);
        let (tokens, secs) = res?;
        let tps = tokens as f64 / secs.max(1e-12);
        println!("{label:<24} {tokens:>8} tokens in {secs:>8.3}s = {tps:>10.1} tok/s");
        measured.push((label, tokens, secs, tps));
    }

    let tps = |name: &str| -> f64 {
        measured.iter().find(|(l, ..)| *l == name).map(|&(.., t)| t).unwrap_or(f64::NAN)
    };
    let speedup_vs_seed = tps("session") / tps("full_forward_serial");
    let speedup_vs_full = tps("session") / tps("full_forward");
    let speedup_threads = tps("session") / tps("session_serial");
    let speedup_simd = tps("session") / tps("session_scalar");
    println!("\nsession vs seed (serial full-forward) : {speedup_vs_seed:>6.2}x  (target >= 3x)");
    println!("session vs threaded full-forward      : {speedup_vs_full:>6.2}x");
    println!("threaded vs serial session kernels    : {speedup_threads:>6.2}x");
    println!("session SIMD vs pinned-scalar kernels : {speedup_simd:>6.2}x");

    let mut pairs: Vec<(&str, Json)> = vec![
        ("preset", Json::Str(preset.clone())),
        ("rows", Json::Num(geo.rollout_batch as f64)),
        ("prompt_len", Json::Num(geo.prompt_len as f64)),
        ("gen_len", Json::Num((geo.seq_len - geo.prompt_len) as f64)),
        ("param_count", Json::Num(geo.param_count as f64)),
        ("kernel", kernel_info_json()),
        ("kernel_threads", Json::Num(threads as f64)),
        ("reps", Json::Num(reps as f64)),
        ("speedup_session_vs_seed", Json::Num(speedup_vs_seed)),
        ("speedup_session_vs_threaded_full_forward", Json::Num(speedup_vs_full)),
        ("speedup_threaded_vs_serial_session", Json::Num(speedup_threads)),
        ("speedup_session_simd_vs_scalar", Json::Num(speedup_simd)),
    ];
    let detail: Vec<(&str, Json)> = measured
        .iter()
        .map(|&(label, tokens, secs, tps)| {
            (
                label,
                Json::obj(vec![
                    ("tokens", Json::Num(tokens as f64)),
                    ("secs", Json::Num(secs)),
                    ("tokens_per_sec", Json::Num(tps)),
                ]),
            )
        })
        .collect();
    pairs.push(("paths", Json::obj(detail)));
    write_bench_json(&PathBuf::from(parsed.str("out")), &Json::obj(pairs))?;
    Ok(())
}
