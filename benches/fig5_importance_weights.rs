//! Figure 5: importance-weight statistics (max / min per step) for the two
//! decoupled methods. The sync method uses a coupled loss and computes no
//! separate importance weight.
//!
//! Paper shape: recompute exhibits much more extreme weights (especially at
//! larger scale, where the recomputed proximal policy drifts from the
//! behaviour policy); loglinear stays contractive — w^alpha is provably
//! pulled toward 1 (Theorem 1).
//!
//!   cargo bench --bench fig5_importance_weights -- --preset setup1

use a3po::bench::{comparison_runs, downsample, BenchConfig};
use a3po::config::Method;

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env_args(
        "fig5_importance_weights",
        "Fig. 5 — max/min importance weights per step, decoupled methods",
    );
    let runs = comparison_runs(&cfg)?;

    println!("\n== Fig. 5: importance-weight extremes over training ({}) ==", cfg.preset);
    for r in &runs {
        if r.method == Method::Sync {
            println!("  {:<12} (coupled loss: no separate importance weight)", "sync");
            continue;
        }
        let pts = downsample(&r.is_weight_curve, 10);
        let series: Vec<String> = pts
            .iter()
            .map(|(s, mx, mn)| format!("({s}, max {mx:.2}, min {mn:.2})"))
            .collect();
        println!("  {:<12} {}", r.method.label(), series.join(" "));
    }

    println!("\n{:<12} {:>12} {:>12} {:>14}", "method", "worst max w", "worst min w", "|log w| p100");
    let mut extremes = vec![];
    for r in &runs {
        if r.method == Method::Sync {
            continue;
        }
        let wmax = r.is_weight_curve.iter().map(|x| x.1).fold(f64::NEG_INFINITY, f64::max);
        let wmin = r.is_weight_curve.iter().map(|x| x.2).fold(f64::INFINITY, f64::min);
        let spread = wmax.max(1.0 / wmin.max(1e-9)).ln();
        extremes.push((r.method, spread));
        println!("{:<12} {:>12.3} {:>12.4} {:>14.3}", r.method.label(), wmax, wmin, spread);
    }
    if let (Some(rec), Some(log)) = (
        extremes.iter().find(|(m, _)| *m == Method::Recompute),
        extremes.iter().find(|(m, _)| *m == Method::Loglinear),
    ) {
        println!(
            "\nweight spread |log w|: recompute {:.3} vs loglinear {:.3}  \
             (paper: loglinear more controlled)",
            rec.1, log.1
        );
    }
    Ok(())
}
