//! Figure 1: proximal-policy log-prob computation time per training step.
//!
//! Paper result: `recompute` needs a full forward pass (4–8 s/step on their
//! 8-GPU testbed); A-3PO's `loglinear` interpolation is ~1.2 ms — a
//! ≥3,000× reduction. `sync` has no prox phase at all.
//!
//! This bench measures the same two operations on this testbed: the
//! `prox_forward` executable over a real training batch vs the Eq. 3
//! elementwise interpolation, and prints the Fig. 1 bars plus the ratio.
//!
//!   cargo bench --bench fig1_prox_time -- --preset setup1

use a3po::bench::{bench, BenchConfig};
use a3po::coordinator::trainer::interp_prox_host;
use a3po::runtime::native::kernels;
use a3po::runtime::{HostTensor, Runtime};
use a3po::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env_args(
        "fig1_prox_time",
        "Fig. 1 — prox log-prob computation time (recompute vs loglinear vs sync)",
    );
    std::env::set_var("A3PO_QUIET", "1");
    let rt = Runtime::load(&a3po::bench::artifact_dir(&cfg), Some(&["init", "prox_forward"]))?;
    let geo = rt.manifest.preset.clone();
    let snapshot = rt.init_params(cfg.seed as i32)?;
    let prox_exec = rt.exec("prox_forward")?;

    // A realistic training batch (token ids + theta/behaviour logps + alphas).
    let mut rng = Pcg64::from_seed(cfg.seed);
    let (b, s) = (geo.train_batch, geo.seq_len);
    let t = s - 1;
    let tokens: Vec<i32> = (0..b * s).map(|_| rng.below(geo.vocab as u64) as i32).collect();
    let theta: Vec<f32> = (0..b * t).map(|_| -rng.next_f32() * 4.0).collect();
    let behav: Vec<f32> = (0..b * t).map(|_| -rng.next_f32() * 4.0).collect();
    let alpha: Vec<f32> = (0..b).map(|_| rng.next_f32()).collect();
    let tokens_t = HostTensor::i32(vec![b, s], tokens);

    println!("\n== Fig. 1: prox log-prob computation time per training step ==");
    println!("preset={} batch={}x{} params={}\n", geo.name, b, s, geo.param_count);

    let iters = 20;
    let recompute = bench(
        &format!(
            "recompute: prox_forward ({} kernel threads)",
            kernels::pool().workers()
        ),
        iters,
        || {
            let mut refs = snapshot.tensor_refs();
            refs.push(&tokens_t);
            let _ = prox_exec.run_refs(&refs).unwrap();
        },
    );
    // The same forward with single-thread kernels: how much of the prox
    // overhead the shared worker pool claws back before A-3PO removes the
    // pass entirely.
    kernels::set_force_serial(true);
    let recompute_serial = bench("recompute: prox_forward (serial kernels)", iters, || {
        let mut refs = snapshot.tensor_refs();
        refs.push(&tokens_t);
        let _ = prox_exec.run_refs(&refs).unwrap();
    });
    kernels::set_force_serial(false);

    let mut sink = 0.0f32;
    let loglinear = bench("loglinear: Eq.3 interpolation (A-3PO)", 200, || {
        let v = interp_prox_host(&theta, &behav, &alpha, t);
        sink += v[0];
    });
    std::hint::black_box(sink);

    println!("\nsync: no prox computation (coupled loss)          0.0 ns by definition");
    let ratio = recompute.mean_ns / loglinear.mean_ns;
    let thread_gain = recompute_serial.mean_ns / recompute.mean_ns;
    println!("\n{:<28} {:>14} {:>14}", "method", "mean / step", "paper");
    println!("{:<28} {:>11.3} ms {:>14}", "recompute", recompute.mean_ns / 1e6, "4000-8000 ms");
    println!(
        "{:<28} {:>11.3} ms {:>14}",
        "recompute (serial kernels)",
        recompute_serial.mean_ns / 1e6,
        format!("{thread_gain:.2}x slower")
    );
    println!("{:<28} {:>11.3} ms {:>14}", "loglinear (A-3PO)", loglinear.mean_ns / 1e6, "1.2 ms");
    println!("{:<28} {:>11.3} ms {:>14}", "sync", 0.0, "0 ms");
    println!(
        "\nrecompute / loglinear = {ratio:.0}x   (paper: >= 3,000x)  {}",
        if ratio >= 100.0 { "— shape reproduced" } else { "" }
    );
    Ok(())
}
