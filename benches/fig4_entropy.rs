//! Figure 4: policy entropy over training steps.
//!
//! Paper shape: all three methods show comparable, healthy entropy decay —
//! the A-3PO approximation does not distort exploration dynamics.
//!
//!   cargo bench --bench fig4_entropy -- --preset setup1 --steps 80

use a3po::bench::{comparison_runs, downsample, BenchConfig};

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env_args(
        "fig4_entropy",
        "Fig. 4 — policy entropy vs training step, 3 methods",
    );
    let runs = comparison_runs(&cfg)?;

    println!("\n== Fig. 4: policy entropy over training ({}) ==", cfg.preset);
    println!("series (step, entropy nats):");
    for r in &runs {
        let pts = downsample(&r.entropy_curve, 12);
        let series: Vec<String> =
            pts.iter().map(|(s, e)| format!("({s}, {e:.3})")).collect();
        println!("  {:<12} {}", r.method.label(), series.join(" "));
    }

    println!("\n{:<12} {:>10} {:>10} {:>12}", "method", "start", "end", "decayed?");
    for r in &runs {
        let start = r.entropy_curve.first().map(|x| x.1).unwrap_or(f64::NAN);
        let end = r.entropy_curve.last().map(|x| x.1).unwrap_or(f64::NAN);
        println!(
            "{:<12} {:>10.3} {:>10.3} {:>12}",
            r.method.label(),
            start,
            end,
            if end <= start { "yes" } else { "no" }
        );
    }
    Ok(())
}
