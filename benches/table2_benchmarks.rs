//! Table 2: benchmark evaluation of the trained policies on the held-out
//! AIME24-like and MATH500-like suites (pass@1 ± stderr).
//!
//! Paper (Setup 2): sync 43.4% avg, recompute 64.7%, loglinear 66.6% —
//! A-3PO matches or beats explicit recomputation.
//!
//! Uses the checkpoints produced by the shared comparison runs (re-running
//! them if the cache is cold).
//!
//!   cargo bench --bench table2_benchmarks -- --preset setup2 --steps 80

use a3po::bench::{comparison_runs, BenchConfig};
use a3po::coordinator::eval::evaluate_pass_at_1;
use a3po::env::suites;
use a3po::runtime::{checkpoint, Runtime};

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env_args(
        "table2_benchmarks",
        "Table 2 — AIME-like / MATH-like pass@1 for the trained policies",
    );
    let runs = comparison_runs(&cfg)?;
    std::env::set_var("A3PO_QUIET", "1");
    let rt = Runtime::load(&a3po::bench::artifact_dir(&cfg), Some(&["decode", "init"]))?;
    let geo = rt.manifest.preset.clone();
    let decoder = rt.decoder()?;

    let all_suites = suites::table2_suites();
    println!("\n== Table 2: benchmark evaluation ({}) ==\n", cfg.preset);
    println!(
        "{:<20} {:>22} {:>22} {:>10}",
        "Method", "AIME24-like pass@1", "MATH500-like pass@1", "Average"
    );
    for r in &runs {
        let snapshot = checkpoint::load(std::path::Path::new(&r.ckpt), &rt.manifest)?;
        let label = match r.method.label() {
            "sync" => "Sync GRPO",
            "recompute" => "Recompute",
            _ => "Loglinear (A-3PO)",
        };
        let mut cells = Vec::new();
        let mut avg = 0.0;
        for suite in &all_suites {
            let fit = suites::fitting(
                suite,
                geo.prompt_len.saturating_sub(1),
                geo.gen_len.saturating_sub(1),
            );
            let (p, se) = evaluate_pass_at_1(&decoder, &snapshot, &fit.problems, &geo, false)?;
            avg += 100.0 * p / all_suites.len() as f64;
            cells.push(format!("{:>6.2}% ± {:>5.2}%", 100.0 * p, 100.0 * se));
        }
        println!("{:<20} {:>22} {:>22} {:>9.2}%", label, cells[0], cells[1], avg);
    }
    println!("\npaper reference (Setup 2): sync 40.0/46.8 (43.4%), recompute 66.7/62.8 (64.7%),");
    println!("                           loglinear 66.7/66.6 (66.6%) — A-3PO >= recompute >> sync.");
    Ok(())
}
