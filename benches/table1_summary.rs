//! Table 1: final evaluation reward and total training time for all three
//! methods at equal epochs.
//!
//! Paper (Setup 1, GSM8K): rewards 0.791–0.797 across methods; times
//! 2.36 h (sync) / 1.82 h (recompute) / 1.53 h (loglinear) — 1.5× speedup.
//! Paper (Setup 2, DAPO-Math): async methods 0.623–0.627 vs sync 0.443;
//! 26.15 / 16.10 / 14.54 h — 1.8× speedup.
//!
//!   cargo bench --bench table1_summary -- --preset setup1 --steps 80

use a3po::bench::{comparison_runs, BenchConfig};

fn main() -> anyhow::Result<()> {
    let cfg = BenchConfig::from_env_args(
        "table1_summary",
        "Table 1 — final eval reward + total training time, 3 methods",
    );
    let runs = comparison_runs(&cfg)?;

    println!("\n== Table 1: final eval reward and training time ({}) ==\n", cfg.preset);
    println!(
        "{:<20} {:>18} {:>20} {:>12}",
        "Method", "Final Eval Reward", "Training Time (s)", "Speedup"
    );
    let sync_time = runs
        .iter()
        .find(|r| r.method.label() == "sync")
        .map(|r| r.total_secs)
        .unwrap_or(f64::NAN);
    for r in &runs {
        let label = match r.method.label() {
            "sync" => "Sync GRPO",
            "recompute" => "Recompute",
            _ => "Loglinear (A-3PO)",
        };
        println!(
            "{:<20} {:>18.3} {:>20.1} {:>11.2}x",
            label,
            r.final_eval,
            r.total_secs,
            sync_time / r.total_secs
        );
    }

    println!("\npaper reference:");
    println!("  Setup 1: 0.793 / 0.797 / 0.791   2.36h / 1.82h / 1.53h  (1.0x/1.3x/1.5x)");
    println!("  Setup 2: 0.443 / 0.627 / 0.623  26.15h / 16.10h / 14.54h (1.0x/1.6x/1.8x)");
    println!("\nexpected shape: loglinear fastest at comparable (or better) final reward.");
    Ok(())
}
