//! Async-pipeline timeline: generation/training overlap and trainer
//! starvation for all three methods, reconstructed from the Chrome traces
//! the runs emit.
//!
//! The paper's speedup claim is that async methods hide generation behind
//! training. This bench makes that visible: each method runs with tracing
//! on, then the trace is parsed back and the wall-clock overlap between
//! `generate` spans (rollout workers, or the trainer inline for sync) and
//! `train`/`prox` spans (trainer) is measured as a fraction of trainer busy
//! time. Sync is the control: its generation and training alternate on one
//! thread, so its overlap is ~0 by construction.
//!
//! Emits `BENCH_timeline.json` plus one Perfetto-loadable trace per method
//! under `<out>/trace/`. Doubles as trace validation in CI: the bench
//! fails if a trace does not parse, if an async trace has spans from fewer
//! than 3 threads, or if the buffer accounting identity breaks.
//!
//!   cargo bench --bench async_timeline -- --steps 6 --workers 2
//!   cargo bench --bench async_timeline -- --preset tiny --out runs/bench

use std::path::PathBuf;

use a3po::bench::{kernel_info_json, write_bench_json};
use a3po::config::{Method, RunOptions, StalenessPolicy};
use a3po::coordinator;
use a3po::util::cli::Args;
use a3po::util::json::Json;

/// Merge `(start, end)` microsecond intervals into a disjoint sorted union.
fn union(mut iv: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    iv.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut out: Vec<(f64, f64)> = Vec::new();
    for (s, e) in iv {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

fn total_len(iv: &[(f64, f64)]) -> f64 {
    iv.iter().map(|(s, e)| e - s).sum()
}

/// Length of the intersection of two disjoint sorted interval unions.
fn intersect_len(a: &[(f64, f64)], b: &[(f64, f64)]) -> f64 {
    let (mut i, mut j, mut acc) = (0usize, 0usize, 0.0f64);
    while i < a.len() && j < b.len() {
        let s = a[i].0.max(b[j].0);
        let e = a[i].1.min(b[j].1);
        if e > s {
            acc += e - s;
        }
        if a[i].1 < b[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    acc
}

/// All complete spans with one of `names`, as `(start_us, end_us)`.
fn spans_named(trace: &Json, names: &[&str]) -> Vec<(f64, f64)> {
    trace
        .get("traceEvents")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter(|e| e.get("ph").as_str() == Some("X"))
        .filter(|e| names.contains(&e.get("name").as_str().unwrap_or("")))
        .map(|e| {
            let ts = e.get("ts").as_f64().unwrap_or(0.0);
            (ts, ts + e.get("dur").as_f64().unwrap_or(0.0))
        })
        .collect()
}

fn distinct_span_tids(trace: &Json) -> usize {
    trace
        .get("traceEvents")
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter(|e| e.get("ph").as_str() == Some("X"))
        .filter_map(|e| e.get("tid").as_i64())
        .collect::<std::collections::BTreeSet<i64>>()
        .len()
}

fn main() -> anyhow::Result<()> {
    let parsed = Args::new(
        "async_timeline",
        "generation/training overlap + trainer starvation from Chrome traces",
    )
    .opt("preset", "tiny", "artifact preset")
    .opt("steps", "6", "RL steps per method")
    .opt("workers", "2", "rollout workers (async methods)")
    .opt("seed", "0", "run seed")
    .opt("out", "runs/bench", "output directory (traces land in <out>/trace/)")
    .flag("bench", "(ignored; passed by cargo bench)")
    .parse();
    let preset = parsed.string("preset");
    let steps = parsed.u64("steps");
    let workers = parsed.usize("workers");
    let seed = parsed.u64("seed");
    let out_dir = parsed.string("out");

    std::env::set_var("A3PO_QUIET", "1");
    let mut rows: Vec<Json> = Vec::new();
    println!("\n== Async-pipeline timeline ({preset}, {steps} steps) ==\n");
    println!(
        "{:<12} {:>10} {:>12} {:>12} {:>10} {:>8}",
        "Method", "total(s)", "overlap", "starvation", "drops", "d_p95"
    );

    for method in Method::ALL {
        let trace_path = PathBuf::from(&out_dir)
            .join("trace")
            .join(format!("trace_{}.json", method.label()));
        let opts = RunOptions {
            preset: preset.clone(),
            out_dir: out_dir.clone(),
            method,
            steps,
            pretrain_steps: 0,
            workers,
            eval_every: 0,
            eval_prompts: 16,
            seed,
            staleness: StalenessPolicy { max_staleness: 16, max_buffered: 256 },
            trace_path: Some(trace_path.to_str().unwrap().into()),
            ..Default::default()
        };
        let out = coordinator::run(&opts)?;
        let tel = &out.telemetry;

        // Parse the trace back: this IS the CI validation of the exporter.
        let text = std::fs::read_to_string(&trace_path)?;
        let trace = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("trace {} unparseable: {e}", trace_path.display()))?;

        let generation = union(spans_named(&trace, &["generate"]));
        let training = union(spans_named(&trace, &["train", "prox"]));
        let train_total = total_len(&training);
        let overlap_frac = if train_total > 0.0 {
            intersect_len(&generation, &training) / train_total
        } else {
            0.0
        };

        assert!(
            tel.buffer.accounting_consistent(),
            "{}: pushed {} != popped {} + dropped {} + remaining {}",
            method.label(),
            tel.buffer.pushed_groups,
            tel.buffer.popped_groups,
            tel.buffer.dropped_stale_groups,
            tel.buffer.remaining_groups
        );
        if method.is_async() {
            assert!(
                distinct_span_tids(&trace) >= 3,
                "{}: async trace needs trainer + >=2 worker threads",
                method.label()
            );
            assert!(
                overlap_frac > 0.0,
                "{}: async generation must overlap training",
                method.label()
            );
            // Starvation is the wait phase over the loop wall clock; the
            // blocked condvar time the buffer saw can't exceed that wait.
            assert!(
                tel.buffer.pop_wait_secs <= tel.trainer_wait_secs + 0.05,
                "{}: buffer pop wait {}s exceeds trainer wait {}s",
                method.label(),
                tel.buffer.pop_wait_secs,
                tel.trainer_wait_secs
            );
        }

        println!(
            "{:<12} {:>10.2} {:>11.1}% {:>11.1}% {:>10} {:>8.1}",
            method.label(),
            out.total_secs,
            overlap_frac * 100.0,
            tel.trainer_starvation_frac() * 100.0,
            tel.buffer.dropped_stale_groups,
            tel.staleness.percentile(95.0),
        );

        rows.push(Json::obj(vec![
            ("method", Json::Str(method.label().into())),
            ("total_secs", Json::Num(out.total_secs)),
            ("final_eval", Json::Num(out.final_eval)),
            ("overlap_fraction", Json::Num(overlap_frac)),
            ("generation_union_secs", Json::Num(total_len(&generation) / 1e6)),
            ("training_union_secs", Json::Num(train_total / 1e6)),
            ("trainer_wait_secs", Json::Num(tel.trainer_wait_secs)),
            ("trainer_starvation_frac", Json::Num(tel.trainer_starvation_frac())),
            ("generation_secs", Json::Num(tel.generation_secs)),
            (
                "worker_utilisation",
                Json::Arr(tel.workers.iter().map(|w| Json::Num(w.utilisation())).collect()),
            ),
            ("buffer", tel.buffer.to_json()),
            ("staleness_p50", Json::Num(tel.staleness.percentile(50.0))),
            ("staleness_p95", Json::Num(tel.staleness.percentile(95.0))),
            ("staleness_max", Json::Num(tel.staleness.max() as f64)),
            ("trace_path", Json::Str(trace_path.to_str().unwrap().into())),
        ]));
    }

    println!("\nexpected shape: async overlap > 0 (generation hides behind training);");
    println!("sync overlap ~ 0 (alternating phases on one thread).");

    let j = Json::obj(vec![
        ("bench", Json::Str("async_timeline".into())),
        ("preset", Json::Str(preset)),
        ("steps", Json::Num(steps as f64)),
        ("workers", Json::Num(workers as f64)),
        ("kernel", kernel_info_json()),
        ("methods", Json::Arr(rows)),
    ]);
    write_bench_json(&PathBuf::from("BENCH_timeline.json"), &j)?;
    Ok(())
}
