//! Quickstart: the smallest end-to-end tour of the system.
//!
//! Loads the `tiny` preset's artifacts, warm-starts the policy with a few
//! supervised steps, runs a handful of A-3PO training steps, and prints the
//! metrics — all in under a minute on a laptop-class CPU.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use a3po::config::{Method, RunOptions};
use a3po::coordinator;

fn main() -> anyhow::Result<()> {
    let parsed = RunOptions::cli("quickstart", "minimal end-to-end A-3PO run").parse();
    let mut opts = RunOptions::from_parsed(&parsed).map_err(anyhow::Error::msg)?;
    // Quickstart defaults: tiny preset, short run, warm start included.
    if parsed.str("preset") == "tiny" && opts.steps == 50 {
        opts.steps = 12;
    }
    if opts.pretrain_steps == 0 {
        opts.pretrain_steps = 30;
    }
    opts.method = Method::Loglinear;
    opts.eval_every = 4;

    eprintln!("== A-3PO quickstart: preset={} ==", opts.preset);
    let out = coordinator::run(&opts)?;

    println!("\n== phase breakdown ==\n{}", out.phases.report());
    println!("== summary ==\n{}", out.summary_json(&opts).dump());
    println!(
        "\nfinal held-out exact-match reward: {:.3}  (total {:.1}s, prox mean {:.2}ms)",
        out.final_eval,
        out.total_secs,
        1e3 * out.phases.mean("prox"),
    );
    Ok(())
}
