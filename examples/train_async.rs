//! End-to-end training driver — the repo's E2E validation workload.
//!
//! Runs the full system on a real (synthetic-data) training job: supervised
//! warm start, then asynchronous RL with the chosen method, periodic
//! held-out evaluation, JSONL metrics, phase breakdown, and a final
//! checkpoint. This is the binary behind the EXPERIMENTS.md runs.
//!
//! ```bash
//! # Setup-1 surrogate, all three methods (paper Fig. 2/3, Table 1):
//! cargo run --release --example train_async -- --preset setup1 \
//!     --method sync      --steps 120 --pretrain-steps 600
//! cargo run --release --example train_async -- --preset setup1 \
//!     --method recompute --steps 120 --pretrain-steps 600
//! cargo run --release --example train_async -- --preset setup1 \
//!     --method loglinear --steps 120 --pretrain-steps 600
//! ```

use a3po::config::RunOptions;
use a3po::coordinator;

fn main() -> anyhow::Result<()> {
    let parsed = RunOptions::cli(
        "train_async",
        "full asynchronous RL training driver (E2E validation workload)",
    )
    .flag("no-ckpt", "skip saving the final checkpoint")
    .parse();
    let mut opts = RunOptions::from_parsed(&parsed).map_err(anyhow::Error::msg)?;
    if opts.pretrain_steps == 0 {
        // The paper starts from instruct-tuned models; an RL run from a
        // random policy mostly measures noise. Default to a real warm start.
        opts.pretrain_steps = 400;
    }

    eprintln!(
        "== train_async: preset={} method={} steps={} (pretrain {}) workers={} ==",
        opts.preset,
        opts.method.label(),
        opts.steps,
        opts.pretrain_steps,
        opts.workers
    );
    let out = coordinator::run(&opts)?;

    if !parsed.flag("no-ckpt") {
        let p = coordinator::save_checkpoint(&opts, &out)?;
        eprintln!("checkpoint: {}.{{json,bin}}", p.display());
    }

    println!("\n== phase breakdown ==\n{}", out.phases.report());
    println!("== exec stats ==");
    for (name, s) in out.runtime.exec_stats() {
        if s.calls > 0 {
            println!(
                "  {:<16} {:>6} calls  {:>9.3}s total  {:>8.2}ms mean",
                name,
                s.calls,
                s.total_secs,
                1e3 * s.total_secs / s.calls as f64
            );
        }
    }
    println!("\n== summary ==\n{}", out.summary_json(&opts).dump());

    // Reward trajectory (condensed) for quick eyeballing.
    println!("\nreward curve (step, shaped, exact):");
    let n = out.logger.steps.len();
    for s in out.logger.steps.iter().step_by((n / 12).max(1)) {
        println!("  {:>5}  {:.3}  {:.3}", s.step, s.reward, s.reward_exact);
    }
    println!("\neval curve (step, exact):");
    for e in &out.logger.evals {
        println!("  {:>5}  {:.3}", e.step, e.eval_reward);
    }
    Ok(())
}
