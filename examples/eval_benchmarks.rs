//! Benchmark evaluation (paper Table 2): load a trained checkpoint and
//! measure pass@1 ± stderr on the frozen AIME24-like and MATH500-like
//! suites.
//!
//! ```bash
//! cargo run --release --example train_async -- --preset setup2 \
//!     --method loglinear --steps 120
//! cargo run --release --example eval_benchmarks -- --preset setup2 \
//!     --ckpt runs/setup2_loglinear
//! ```

use std::path::PathBuf;

use a3po::coordinator::eval::evaluate_pass_at_1;
use a3po::env::suites;
use a3po::runtime::{checkpoint, Runtime};
use a3po::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let parsed = Args::new("eval_benchmarks", "Table-2 style benchmark evaluation")
        .opt("preset", "setup2", "artifact preset")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt_optional("ckpt", "checkpoint base path (default: fresh init)")
        .opt("samples", "1", "sampled attempts per problem (pass@1 repeats)")
        .flag("greedy", "use greedy decoding")
        .parse();

    std::env::set_var("A3PO_QUIET", "1");
    let dir = PathBuf::from(parsed.str("artifacts")).join(parsed.str("preset"));
    let rt = Runtime::load(&dir, Some(&["decode", "init"]))?;
    let geo = rt.manifest.preset.clone();

    let snapshot = match parsed.get("ckpt") {
        Some(base) => {
            eprintln!("loading checkpoint {base}");
            checkpoint::load(&PathBuf::from(base), &rt.manifest)?
        }
        None => {
            eprintln!("no --ckpt: evaluating a freshly initialised policy (baseline floor)");
            rt.init_params(0)?
        }
    };
    let decoder = rt.decoder()?;

    println!(
        "\n{:<16} {:>6} {:>20}   note",
        "suite", "n", "pass@1 ± stderr"
    );
    let mut avg = 0.0;
    let all = suites::table2_suites();
    for suite in &all {
        let fit = suites::fitting(
            suite,
            geo.prompt_len.saturating_sub(1),
            geo.gen_len.saturating_sub(1),
        );
        let skipped = suite.problems.len() - fit.problems.len();
        let (p, se) =
            evaluate_pass_at_1(&decoder, &snapshot, &fit.problems, &geo, parsed.flag("greedy"))?;
        avg += 100.0 * p / all.len() as f64;
        println!(
            "{:<16} {:>6} {:>12.2}% ± {:>4.2}%   {}",
            suite.name,
            fit.problems.len(),
            100.0 * p,
            100.0 * se,
            if skipped > 0 {
                format!("({skipped} problems exceed this preset's window)")
            } else {
                String::new()
            }
        );
    }
    println!("{:<16} {:>6} {:>12.2}%", "Average", "", avg);
    println!(
        "\npaper Table 2 (Setup 2): sync 43.4%, recompute 64.7%, loglinear (A-3PO) 66.6%"
    );
    Ok(())
}
