//! Ablation: how does A-3PO behave as staleness grows, and does the Eq. 4
//! schedule matter?
//!
//! Two sweeps the paper motivates but does not plot:
//!   1. Controlled staleness: inject d = 0, 1, 2, 4, 8 and record the
//!      importance-weight spread and clip counts — Theorem 1 says the
//!      ratios contract toward 1 as d grows (alpha = 1/d shrinks).
//!   2. Alpha-schedule ablation: Eq. 4's 1/d vs 1/d^2 vs constant vs
//!      behaviour-anchoring, at fixed injected staleness.
//!
//! ```bash
//! cargo run --release --example staleness_sweep -- --preset tiny --steps 12
//! ```

use a3po::config::{AlphaSchedule, Method, RunOptions};
use a3po::coordinator;

fn main() -> anyhow::Result<()> {
    let parsed = RunOptions::cli("staleness_sweep", "A-3PO staleness / alpha-schedule ablations")
        .parse();
    let mut base = RunOptions::from_parsed(&parsed).map_err(anyhow::Error::msg)?;
    base.method = Method::Loglinear;
    if base.pretrain_steps == 0 {
        base.pretrain_steps = 100;
    }
    base.eval_every = 0;
    std::env::set_var("A3PO_QUIET", "1");

    println!("\n== sweep 1: injected staleness (alpha = 1/d, Eq. 4) ==");
    println!(
        "{:>3} {:>8} {:>12} {:>12} {:>12} {:>10}",
        "d", "alpha", "max |log w|", "clip/step", "reward", "eval"
    );
    for d in [0u64, 1, 2, 4, 8] {
        let mut opts = base.clone();
        opts.inject_staleness = d;
        opts.staleness.max_staleness = d + 8;
        let out = coordinator::run(&opts)?;
        let spread = out
            .logger
            .steps
            .iter()
            .map(|s| s.train.max_is_weight.max(1.0 / s.train.min_is_weight.max(1e-9)).ln())
            .fold(f64::NEG_INFINITY, f64::max);
        let clips: f64 = out.logger.steps.iter().map(|s| s.train.clipped_tokens).sum::<f64>()
            / out.logger.steps.len() as f64;
        let reward = out.logger.steps.last().map(|s| s.reward).unwrap_or(0.0);
        let alpha = AlphaSchedule::InverseD.alpha(d);
        println!(
            "{:>3} {:>8.3} {:>12.4} {:>12.2} {:>12.3} {:>10.3}",
            d, alpha, spread, clips, reward, out.final_eval
        );
    }
    println!("(expected: |log w| spread grows with d but ratios stay contractive,");
    println!(" clipping stays low — Theorem 1's stability under staleness)");

    println!("\n== sweep 2: alpha schedule at injected staleness d = 4 ==");
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>10}",
        "schedule", "max |log w|", "clip/step", "reward", "eval"
    );
    for (name, sched) in [
        ("1/d (Eq. 4)", AlphaSchedule::InverseD),
        ("1/d^2", AlphaSchedule::InverseD2),
        ("const 0.5", AlphaSchedule::Constant(0.5)),
        ("behaviour", AlphaSchedule::Behaviour),
    ] {
        let mut opts = base.clone();
        opts.inject_staleness = 4;
        opts.staleness.max_staleness = 16;
        opts.alpha_schedule = sched;
        let out = coordinator::run(&opts)?;
        let spread = out
            .logger
            .steps
            .iter()
            .map(|s| s.train.max_is_weight.max(1.0 / s.train.min_is_weight.max(1e-9)).ln())
            .fold(f64::NEG_INFINITY, f64::max);
        let clips: f64 = out.logger.steps.iter().map(|s| s.train.clipped_tokens).sum::<f64>()
            / out.logger.steps.len() as f64;
        let reward = out.logger.steps.last().map(|s| s.reward).unwrap_or(0.0);
        println!(
            "{:<14} {:>12.4} {:>12.2} {:>12.3} {:>10.3}",
            name, spread, clips, reward, out.final_eval
        );
    }
    println!("(behaviour-anchoring maximises the trust-region pull toward stale policies;");
    println!(" Eq. 4's 1/d keeps weights contractive while still correcting off-policy data)");
    Ok(())
}
